//! SPICE substrate — DC operating-point and transient simulator for the
//! generated memristor netlists (the paper validates on SPICE; DESIGN.md §3
//! maps their PSpice runs to this MNA engine).
//!
//! Supported elements (all the generated netlists need):
//!   R  resistor                      V  independent voltage source
//!   E  VCVS (op-amp = high-gain E)   I  independent current source
//!   D  diode (Shockley, solved by Newton-Raphson companion iteration)
//!   C  capacitor                     L  inductor
//!
//! Node 0 is ground. The engine performs Modified Nodal Analysis: node
//! voltages plus branch currents for V, E and L elements; diodes are
//! linearized per Newton iteration until max voltage delta < tol.
//! Capacitors and inductors are open / short circuits at DC and become
//! companion conductances under [`transient`] integration; V and I sources
//! optionally carry a time-varying [`transient::Waveform`]
//! ([`Circuit::set_waveform`]).
//!
//! Solves are **factor-once / solve-many**: every [`Circuit`] carries a
//! cached sparse LU factorization ([`factor`]) keyed on the stamped
//! topology. Newton iterations and element-value edits reuse the symbolic
//! analysis and only replay the numeric elimination; [`Circuit::set_vsource`]
//! edits touch the RHS alone, so sweeps and repeated crossbar reads are
//! pure O(nnz(L+U)) re-solves. Factored solutions are residual-guarded and
//! fall back to the reference solver ([`solve::SparseSys::solve_with_stats`],
//! reachable directly via [`Circuit::dc_op_stats_reference`]) whenever the
//! cached pivot order goes stale.
//!
//! # Direct vs iterative selection
//!
//! Giant monolithic crossbars (the paper's 2050x1024 case and beyond) are
//! memory-bound under even one complete factorization. [`Circuit`]
//! therefore carries a [`krylov::SolverStrategy`]
//! ([`Circuit::set_solver`], threaded from `PipelineBuilder` and the
//! `--solver` CLI flag): `Direct` always uses the factor engine,
//! `Iterative` always runs preconditioned restarted GMRES
//! ([`krylov::gmres`]), and the default `Auto` switches to GMRES above the
//! monolithic pattern-size threshold ([`krylov::AUTO_NNZ_THRESHOLD`]) so
//! segmented circuits keep the exact direct behaviour.
//!
//! **Preconditioner-reuse contract**: an iterative solve preconditions
//! with, in order of preference, (1) an already-cached complete
//! [`factor::Numeric`] whose pattern matches — even when its *values* are
//! stale (programming noise, drift, Newton updates), the old LU is a
//! near-perfect preconditioner, so warm re-solves converge in a handful of
//! iterations with no refactorization; (2) the cached [`krylov::Ilu0`]
//! pattern, re-swept in place only when stamp values changed; (3) a fresh
//! ILU(0) analysis (cold solve), cached for the next call. Every iterative
//! solution passes the same scaled-residual gate as the factored path and
//! falls back to the direct engine on any failure, so the iterative path
//! is never less accurate — solutions agree with direct solves within the
//! 1e-6 pinned test tolerance (typically ~1e-10).
//!
//! # Cached-factorization contracts: DC vs transient
//!
//! Both analyses ride the same factor-once/solve-many substrate, but they
//! hold the cached [`factor::Symbolic`] to different promises:
//!
//! - **DC** (`dc_op*`): the symbolic analysis is keyed on the stamped
//!   *topology* and cached on the [`Circuit`]. Newton iterations re-stamp
//!   values at the same pattern (nonlinear companion entries use
//!   `add_keep`, so zero coefficients at the initial operating point still
//!   reserve their slots), value edits trigger a numeric refactor, and
//!   [`Circuit::set_vsource`] edits are RHS-only pure re-solves. The cache
//!   survives *across calls* and is invalidated only by topology edits.
//! - **Transient** ([`transient::tran_batch`]): the companion stamps for C
//!   and L change *value* with the timestep `h` but never *pattern* —
//!   capacitor conductances and inductor branch self-terms are stamped
//!   with `add_keep`, so the DC-initialization stamp (`G_eq = 0`: caps
//!   open, inductors short) emits the identical triplet stream as every
//!   timestep at every `h`. One symbolic analysis therefore serves the DC
//!   init plus *all* timesteps of *all* RHS columns; an `h` change is a
//!   numeric refactor (for TR-BDF2, the two stage matrices share the one
//!   `Symbolic` through two `Numeric`s) and a fixed-`h` run after the
//!   first step is pure multi-RHS substitution. The transient engine owns
//!   its factorization *locally* for the duration of the sweep — it never
//!   touches the circuit's DC cache, so interleaving `dc_op` calls with
//!   transient runs cannot thrash either contract.

pub mod factor;
pub mod krylov;
pub mod solve;
pub mod transient;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering as MemOrdering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::backend::{self, BackendChoice};
use solve::{solve_dense, SparseSys};

/// Process-wide count of **warm** iterative→direct fallback events: an
/// [`krylov::SolverStrategy::Iterative`] (or `Auto`-promoted) solve that
/// held a cached preconditioner for the current pattern yet still failed
/// its residual gate, broke down, or did not converge, and was silently
/// re-run on the direct factor engine. Accuracy is unaffected by
/// construction, but a climbing count means the preconditioner has gone
/// stale (e.g. heavy conductance drift) — surfaced by
/// `coordinator::Snapshot` and `memx report` so the degradation is
/// observable at serve time. Cold-start failures (no cached state yet, the
/// fresh ILU(0) analysis or sweep failed) land in
/// [`solver_cold_fallbacks`] instead: earlier versions conflated the two,
/// so a transient sweep's first-step cold fallback inflated the staleness
/// signal the watchdog alarms on.
static SOLVER_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of **cold** iterative→direct fallback events: the
/// solve had no cached preconditioner for this pattern and the fresh
/// analysis/sweep/solve failed. These are expected on structurally hostile
/// first solves and say nothing about drift staleness (see
/// [`solver_fallbacks`]).
static SOLVER_COLD_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of GMRES inner iterations. Bumped **inside**
/// [`krylov::gmres_kern`] — i.e. on the `pool::par_map` worker threads of
/// a batched solve — rather than from the sequentially-aggregated
/// [`solve::SolveStats`], so the count is exact under `workers >= 2`
/// (the aggregation path once lost per-column stats when a later column
/// errored; the atomic never does).
static GMRES_ITERATIONS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of iterative solves served by a *cached* (warm)
/// preconditioner — the global twin of the per-solve
/// [`solve::SolveStats::precond_reused`] flag, kept as an explicit atomic
/// so multi-threaded batch solves can't under-report it.
static PRECOND_REUSES: AtomicU64 = AtomicU64::new(0);

/// Current value of the process-wide warm iterative→direct fallback
/// counter (cached preconditioner existed but failed mid-sweep).
pub fn solver_fallbacks() -> u64 {
    SOLVER_FALLBACKS.load(MemOrdering::Relaxed)
}

/// Current value of the process-wide cold iterative→direct fallback
/// counter (no cached preconditioner yet; fresh analysis failed).
pub fn solver_cold_fallbacks() -> u64 {
    SOLVER_COLD_FALLBACKS.load(MemOrdering::Relaxed)
}

/// Current value of the process-wide GMRES inner-iteration counter.
pub fn gmres_iterations() -> u64 {
    GMRES_ITERATIONS.load(MemOrdering::Relaxed)
}

/// Current value of the process-wide warm-preconditioner reuse counter.
pub fn precond_reuses() -> u64 {
    PRECOND_REUSES.load(MemOrdering::Relaxed)
}

/// Worker-thread-safe bump of the process iteration counter (called from
/// inside the GMRES kernel, possibly on `par_map` workers).
pub(crate) fn add_gmres_iterations(n: u64) {
    if n > 0 {
        GMRES_ITERATIONS.fetch_add(n, MemOrdering::Relaxed);
    }
}

/// Circuit element.
#[derive(Debug, Clone, PartialEq)]
pub enum Element {
    /// name, n+, n-, ohms
    Resistor(String, usize, usize, f64),
    /// name, n+, n-, volts
    Vsource(String, usize, usize, f64),
    /// name, n+, n-, amps (flows n+ -> n-)
    Isource(String, usize, usize, f64),
    /// name, out+, out-, ctrl+, ctrl-, gain
    Vcvs(String, usize, usize, usize, usize, f64),
    /// name, out+, out-, ctrl+, ctrl-, transconductance (S): a current
    /// `gm * (V(ctrl+) - V(ctrl-))` flows out+ -> out-. Stamps into the
    /// node rows only — no branch unknown, so a VCCS whose output nodes
    /// carry no other conductance produces the zero-diagonal pattern the
    /// pivoting tests in `netlist::validate` hammer.
    Vccs(String, usize, usize, usize, usize, f64),
    /// name, anode, cathode, saturation current, emission*Vt
    Diode(String, usize, usize, f64, f64),
    /// name, out (vs ground), ctrl_a, ctrl_b, gain: V(out) = gain*V(a)*V(b).
    /// Behavioural analog multiplier (Gilbert-cell abstraction, Fig 4b);
    /// nonlinear — solved by the same Newton loop as diodes.
    Mult(String, usize, usize, usize, f64),
    /// name, n+, n-, farads. Open at DC; companion conductance under
    /// [`transient`] integration (stamped with `add_keep`, so the pattern
    /// is identical at DC and at every timestep).
    Capacitor(String, usize, usize, f64),
    /// name, n+, n-, henries. Short at DC (carries a branch-current
    /// unknown like a V source); companion branch under [`transient`]
    /// integration.
    Inductor(String, usize, usize, f64),
}

impl Element {
    pub fn name(&self) -> &str {
        match self {
            Element::Resistor(n, ..)
            | Element::Vsource(n, ..)
            | Element::Isource(n, ..)
            | Element::Vcvs(n, ..)
            | Element::Vccs(n, ..)
            | Element::Diode(n, ..)
            | Element::Mult(n, ..)
            | Element::Capacitor(n, ..)
            | Element::Inductor(n, ..) => n,
        }
    }
}

/// Cached factorization state. Lives behind a `Mutex` so `dc_op(&self)`
/// stays shareable across the segmented par_map solvers; cloning a circuit
/// clones the cache contents.
#[derive(Debug, Default)]
struct FactorCache(Mutex<Option<CacheState>>);

#[derive(Debug, Clone)]
enum CacheState {
    /// a live factorization for the current topology
    Ready(CacheEntry),
    /// symbolic analysis failed structurally for this topology (e.g.
    /// fill-in explosion) — skip re-attempting it while the cheap
    /// fingerprint matches, and go straight to the reference solver
    Unusable { ordering: solve::Ordering, dim: usize, nnz: usize },
    /// the iterative path's ILU(0) preconditioner for the current topology
    /// (pattern + transversal cached; values re-swept in place on change)
    Ilu(krylov::Ilu0),
}

#[derive(Debug, Clone)]
struct CacheEntry {
    ordering: solve::Ordering,
    numeric: factor::Numeric,
}

/// Outcome of one preconditioned-Krylov attempt (see
/// [`Circuit::solve_krylov_with`]).
enum KrylovAttempt<R> {
    /// Solved; the flag records whether a cached preconditioner served.
    Solved(R, bool),
    /// A cached preconditioner for this pattern existed but the sweep or
    /// solve failed — drift-staleness signal.
    WarmFailure,
    /// No cached state; the fresh ILU(0) analysis/sweep/solve failed.
    ColdFailure,
}

impl<R> KrylovAttempt<R> {
    /// Bump the process-wide fallback counter matching this failure (no-op
    /// for `Solved`). Centralized here so every caller that falls back to
    /// the direct engine reports the same way — including the typed trace
    /// event, so a fallback shows up inline in the span timeline.
    fn count_fallback(&self) {
        match self {
            KrylovAttempt::Solved(..) => {}
            KrylovAttempt::WarmFailure => {
                SOLVER_FALLBACKS.fetch_add(1, MemOrdering::Relaxed);
                crate::telemetry::event(crate::telemetry::Event::SolverFallback { cold: false });
            }
            KrylovAttempt::ColdFailure => {
                SOLVER_COLD_FALLBACKS.fetch_add(1, MemOrdering::Relaxed);
                crate::telemetry::event(crate::telemetry::Event::SolverFallback { cold: true });
            }
        }
    }
}

impl Clone for FactorCache {
    fn clone(&self) -> Self {
        let inner = match self.0.lock() {
            Ok(g) => g.clone(),
            Err(_) => None,
        };
        FactorCache(Mutex::new(inner))
    }
}

/// Scaled residual acceptance for factored solutions: ||Ax-b||_inf must be
/// tiny relative to the largest term that formed it. Stale pivot orders
/// produce O(scale) residuals; healthy solves sit many orders below the
/// 1e-7 gate (crossbar/TIA systems measure ~1e-10), so the gate rejects
/// genuine pivot failures without spuriously discarding valid factors on
/// ill-conditioned corner cases.
fn residual_ok(sys: &SparseSys, b: &[f64], x: &[f64]) -> bool {
    let mut r = b.to_vec();
    let mut scale = 1.0f64;
    for &bv in b {
        scale = scale.max(bv.abs());
    }
    for &(i, j, v) in sys.iter_triplets() {
        let t = v * x[j];
        r[i] -= t;
        scale = scale.max(t.abs());
    }
    r.iter().all(|v| v.abs() <= 1e-7 * scale)
}

/// A flat circuit: elements over integer nodes (0 = ground).
#[derive(Debug, Clone, Default)]
pub struct Circuit {
    pub title: String,
    pub elements: Vec<Element>,
    next_node: usize,
    names: BTreeMap<String, usize>,
    factor_cache: FactorCache,
    solver: krylov::SolverStrategy,
    backend: BackendChoice,
    /// Time-varying source waveforms, keyed by element index (V/I sources
    /// only). DC analyses use the element's static value (kept at the
    /// waveform's t=0 sample); [`transient`] evaluates the waveform per
    /// timestep. A side table rather than wider source variants, so every
    /// existing construction/update site keeps its shape.
    waves: BTreeMap<usize, transient::Waveform>,
}

impl Circuit {
    pub fn new(title: &str) -> Self {
        let mut c = Circuit { title: title.to_string(), ..Default::default() };
        c.names.insert("0".into(), 0);
        c.names.insert("gnd".into(), 0);
        c.next_node = 1;
        c
    }

    /// Intern a named node.
    pub fn node(&mut self, name: &str) -> usize {
        if let Some(&n) = self.names.get(name) {
            return n;
        }
        let n = self.next_node;
        self.next_node += 1;
        self.names.insert(name.to_string(), n);
        n
    }

    /// Fresh anonymous node.
    pub fn fresh(&mut self) -> usize {
        let n = self.next_node;
        self.next_node += 1;
        self.names.insert(format!("_n{n}"), n);
        n
    }

    pub fn node_count(&self) -> usize {
        self.next_node
    }

    pub fn node_named(&self, name: &str) -> Option<usize> {
        self.names.get(name).copied()
    }

    /// Node id -> name table (index = node id). Every node has at least one
    /// name ([`Circuit::node`] interns, [`Circuit::fresh`] synthesizes
    /// `_n<id>`); aliased ids keep the lexicographically first name, so
    /// ground renders as `"0"`. This is the inverse map the interchange
    /// emitter ([`crate::netlist::interchange`]) serializes cards from.
    pub fn node_names(&self) -> Vec<String> {
        let mut out = vec![String::new(); self.next_node.max(1)];
        for (name, &id) in &self.names {
            if out[id].is_empty() {
                out[id] = name.clone();
            }
        }
        for (id, name) in out.iter_mut().enumerate() {
            if name.is_empty() {
                *name = format!("_n{id}");
            }
        }
        out
    }

    pub fn resistor(&mut self, name: &str, a: usize, b: usize, ohms: f64) {
        self.elements.push(Element::Resistor(name.into(), a, b, ohms));
    }

    pub fn vsource(&mut self, name: &str, a: usize, b: usize, volts: f64) {
        self.elements.push(Element::Vsource(name.into(), a, b, volts));
    }

    pub fn isource(&mut self, name: &str, a: usize, b: usize, amps: f64) {
        self.elements.push(Element::Isource(name.into(), a, b, amps));
    }

    pub fn vcvs(&mut self, name: &str, op: usize, om: usize, cp: usize, cm: usize, gain: f64) {
        self.elements.push(Element::Vcvs(name.into(), op, om, cp, cm, gain));
    }

    pub fn vccs(&mut self, name: &str, op: usize, om: usize, cp: usize, cm: usize, gm: f64) {
        self.elements.push(Element::Vccs(name.into(), op, om, cp, cm, gm));
    }

    pub fn mult(&mut self, name: &str, out: usize, a: usize, b: usize, gain: f64) {
        self.elements.push(Element::Mult(name.into(), out, a, b, gain));
    }

    pub fn capacitor(&mut self, name: &str, a: usize, b: usize, farads: f64) {
        self.elements.push(Element::Capacitor(name.into(), a, b, farads));
    }

    pub fn inductor(&mut self, name: &str, a: usize, b: usize, henries: f64) {
        self.elements.push(Element::Inductor(name.into(), a, b, henries));
    }

    /// Attach a time-varying waveform to the V or I source at element
    /// index `idx` (see [`Circuit::vsource_index`]). The element's static
    /// value is set to the waveform's t=0 sample so DC analyses see the
    /// pre-pulse operating point; [`transient`] sweeps evaluate the
    /// waveform per timestep.
    pub fn set_waveform(&mut self, idx: usize, wave: transient::Waveform) -> Result<()> {
        let v0 = wave.eval(0.0);
        match self.elements.get_mut(idx) {
            Some(Element::Vsource(_, _, _, v)) | Some(Element::Isource(_, _, _, v)) => {
                *v = v0;
            }
            _ => bail!("element {idx} is not a V or I source"),
        }
        self.waves.insert(idx, wave);
        Ok(())
    }

    /// Waveform attached to element `idx`, if any.
    pub fn waveform_at(&self, idx: usize) -> Option<&transient::Waveform> {
        self.waves.get(&idx)
    }

    /// Convenience builder: a V source driven by `wave` (static value =
    /// the t=0 sample). Returns the element index for per-column scaling
    /// in [`transient::tran_batch`].
    pub fn vsource_wave(
        &mut self,
        name: &str,
        a: usize,
        b: usize,
        wave: transient::Waveform,
    ) -> usize {
        let idx = self.elements.len();
        self.vsource(name, a, b, wave.eval(0.0));
        self.waves.insert(idx, wave);
        idx
    }

    /// Convenience builder: an I source driven by `wave` (see
    /// [`Circuit::vsource_wave`]).
    pub fn isource_wave(
        &mut self,
        name: &str,
        a: usize,
        b: usize,
        wave: transient::Waveform,
    ) -> usize {
        let idx = self.elements.len();
        self.isource(name, a, b, wave.eval(0.0));
        self.waves.insert(idx, wave);
        idx
    }

    pub fn diode(&mut self, name: &str, a: usize, k: usize) {
        // 1N4148-ish: Is = 2.52e-9 A, n*Vt = 1.752 * 25.85 mV
        self.elements.push(Element::Diode(name.into(), a, k, 2.52e-9, 1.752 * 0.02585));
    }

    /// Ideal op-amp as a VCVS with high open-loop gain (paper's ideal-TIA
    /// assumption). out is referenced to ground.
    pub fn opamp(&mut self, name: &str, vplus: usize, vminus: usize, out: usize) {
        self.vcvs(name, out, 0, vplus, vminus, 1e6);
    }

    /// Update the value of an existing V source (reprogramming crossbar
    /// inputs between solves without rebuilding the circuit). Source edits
    /// only change the RHS, so the next `dc_op` on a linear circuit is a
    /// pure cached re-solve — no refactorization.
    pub fn set_vsource(&mut self, name: &str, volts: f64) -> Result<()> {
        match self.vsource_index(name) {
            Some(i) => self.set_vsource_at(i, volts),
            None => bail!("no vsource named '{name}'"),
        }
    }

    /// Element index of a named V source, for O(1) repeated updates via
    /// [`Circuit::set_vsource_at`] (sweep and batch drivers resolve names
    /// once instead of scanning the element list per point).
    pub fn vsource_index(&self, name: &str) -> Option<usize> {
        self.elements
            .iter()
            .position(|e| matches!(e, Element::Vsource(n, ..) if n == name))
    }

    /// O(1) variant of [`Circuit::set_vsource`]; `idx` from
    /// [`Circuit::vsource_index`].
    pub fn set_vsource_at(&mut self, idx: usize, volts: f64) -> Result<()> {
        match self.elements.get_mut(idx) {
            Some(Element::Vsource(_, _, _, v)) => {
                *v = volts;
                Ok(())
            }
            _ => bail!("element {idx} is not a V source"),
        }
    }

    /// Select the linear-solver strategy for subsequent solves (see the
    /// module docs; default [`krylov::SolverStrategy::Auto`]).
    pub fn set_solver(&mut self, solver: krylov::SolverStrategy) {
        self.solver = solver;
    }

    pub fn solver(&self) -> krylov::SolverStrategy {
        self.solver
    }

    /// Select the dense-kernel [`crate::backend`] for subsequent solves
    /// (default [`BackendChoice::Auto`]: `MEMX_BACKEND` env override, else
    /// the portable-SIMD CPU kernels).
    pub fn set_backend(&mut self, backend: BackendChoice) {
        self.backend = backend;
    }

    pub fn backend(&self) -> BackendChoice {
        self.backend
    }

    fn num_branches(&self) -> usize {
        self.elements
            .iter()
            .filter(|e| {
                matches!(
                    e,
                    Element::Vsource(..)
                        | Element::Vcvs(..)
                        | Element::Mult(..)
                        | Element::Inductor(..)
                )
            })
            .count()
    }

    /// DC operating point. Returns node voltages (index = node id).
    pub fn dc_op(&self) -> Result<Vec<f64>> {
        self.dc_op_with(solve::Ordering::Smart)
    }

    /// DC operating point under an explicit elimination ordering (the Fig 7
    /// benchmarks contrast Natural vs Smart — see spice::solve docs).
    pub fn dc_op_with(&self, ordering: solve::Ordering) -> Result<Vec<f64>> {
        Ok(self.dc_op_stats(ordering)?.0)
    }

    /// DC operating point + solver work/memory counters (Fig 7 reads the
    /// peak resident matrix entries of monolithic vs segmented solves).
    ///
    /// Runs on the factored engine: the symbolic factorization is cached on
    /// this circuit and shared across Newton iterations, repeated calls,
    /// and [`Circuit::set_vsource`] sweeps (source edits are RHS-only pure
    /// re-solves). Falls back to [`Circuit::dc_op_stats_reference`]
    /// behaviour whenever the factored path cannot certify its result.
    pub fn dc_op_stats(
        &self,
        ordering: solve::Ordering,
    ) -> Result<(Vec<f64>, solve::SolveStats)> {
        self.dc_op_impl(ordering, true)
    }

    /// Reference DC operating point: per-call dense (small circuits) or
    /// hash-map sparse elimination, exactly the pre-factorization engine.
    /// Kept as the correctness baseline for tests and the cold-solve side
    /// of the benches.
    pub fn dc_op_stats_reference(
        &self,
        ordering: solve::Ordering,
    ) -> Result<(Vec<f64>, solve::SolveStats)> {
        self.dc_op_impl(ordering, false)
    }

    fn dc_op_impl(
        &self,
        ordering: solve::Ordering,
        factored: bool,
    ) -> Result<(Vec<f64>, solve::SolveStats)> {
        let n_nodes = self.node_count();
        let n_br = self.num_branches();
        let dim = (n_nodes - 1) + n_br; // ground eliminated
        let has_diodes = self
            .elements
            .iter()
            .any(|e| matches!(e, Element::Diode(..) | Element::Mult(..)));

        let mut v_nodes = vec![0.0; n_nodes];
        let mut stats = solve::SolveStats::direct(0, dim);
        let max_newton = if has_diodes { 200 } else { 1 };
        for _it in 0..max_newton {
            let sys = self.stamp(dim, n_nodes, &v_nodes)?;
            let x = if factored {
                let (x, st) = if self.solver.wants_iterative(sys.nnz()) {
                    match self.solve_krylov(&sys) {
                        Some(r) => r,
                        // iterative failure (non-convergence, structural
                        // singularity, residual gate — warm/cold counter
                        // already bumped): direct semantics
                        None => self.solve_factored(&sys, ordering)?,
                    }
                } else {
                    self.solve_factored(&sys, ordering)?
                };
                stats = st;
                x
            } else if dim <= 220 {
                // dense path for small circuits (activation modules)
                let mut a = vec![vec![0.0; dim]; dim];
                for &(i, j, v) in sys.iter_triplets() {
                    a[i][j] += v;
                }
                stats = solve::SolveStats::direct(dim * dim, dim);
                solve_dense(&a, &sys.b).context("dense MNA solve")?
            } else {
                let (x, st) = sys.solve_with_stats(ordering).context("sparse MNA solve")?;
                stats = st;
                x
            };
            let mut new_v = vec![0.0; n_nodes];
            new_v[1..].copy_from_slice(&x[..n_nodes - 1]);
            // damped Newton update for diode convergence
            let mut delta = 0.0f64;
            for i in 0..n_nodes {
                delta = delta.max((new_v[i] - v_nodes[i]).abs());
            }
            if has_diodes {
                for i in 0..n_nodes {
                    let step = new_v[i] - v_nodes[i];
                    v_nodes[i] += step.clamp(-0.5, 0.5); // limit junction jumps
                }
            } else {
                v_nodes = new_v;
            }
            if delta < 1e-9 || !has_diodes {
                return Ok((v_nodes, stats));
            }
        }
        Ok((v_nodes, stats)) // damped iterations exhausted; callers check outputs
    }

    /// Factored solve of one stamped system, reusing the cached
    /// factorization when the topology matches. Tries, in order:
    /// cached re-solve / numeric refactor -> fresh symbolic analysis ->
    /// reference solver; every factored result is residual-certified.
    fn solve_factored(
        &self,
        sys: &SparseSys,
        ordering: solve::Ordering,
    ) -> Result<(Vec<f64>, solve::SolveStats)> {
        let kern = backend::resolve(self.backend);
        let mut sp = crate::telemetry::span("solve_factored", "kernel");
        sp.set_arg("n", sys.n as f64);
        let mut guard = self.factor_cache.0.lock().unwrap_or_else(|p| p.into_inner());
        match guard.as_mut() {
            Some(CacheState::Ready(entry)) if entry.ordering == ordering => {
                if let Ok(unchanged) = entry.numeric.assemble(sys) {
                    let factored = unchanged || entry.numeric.refactor().is_ok();
                    if factored {
                        let t0 = Instant::now();
                        if let Ok(x) = entry.numeric.solve_kern(&sys.b, kern) {
                            let subst_ns = t0.elapsed().as_nanos() as u64;
                            if residual_ok(sys, &sys.b, &x) {
                                let mut st = entry.numeric.stats();
                                st.backend = kern.name();
                                st.subst_ns = subst_ns;
                                return Ok((x, st));
                            }
                        }
                    }
                }
            }
            Some(CacheState::Unusable { ordering: o, dim, nnz })
                if *o == ordering && *dim == sys.n && *nnz == sys.nnz() =>
            {
                // analysis already failed for this topology: don't re-run
                // the doomed (if bounded) analysis on every solve of a sweep
                return sys.solve_with_stats(ordering).context("sparse MNA solve");
            }
            _ => {}
        }
        // cache miss or stale pivots: fresh analysis with the current values
        match factor::factor_solve_kern(sys, ordering, kern) {
            Ok((x, numeric)) => {
                if residual_ok(sys, &sys.b, &x) {
                    let mut st = numeric.stats();
                    st.backend = kern.name();
                    *guard = Some(CacheState::Ready(CacheEntry { ordering, numeric }));
                    return Ok((x, st));
                }
                // certification failed for these *values* — the topology may
                // still factor fine at the next Newton point, so don't mark
                // it unusable
                *guard = None;
                sys.solve_with_stats(ordering).context("sparse MNA solve")
            }
            Err(_) => {
                // structural failure (singular / fill explosion): remember it
                *guard = Some(CacheState::Unusable {
                    ordering,
                    dim: sys.n,
                    nnz: sys.nnz(),
                });
                sys.solve_with_stats(ordering).context("sparse MNA solve")
            }
        }
    }

    /// Resolve a preconditioner per the module-docs reuse contract and run
    /// `run` against it under the cache lock. Failures distinguish the
    /// warm path (a cached preconditioner for this pattern existed but the
    /// solve failed mid-sweep — the staleness signal the serving watchdog
    /// cares about) from the cold path (no cached state yet; the fresh
    /// ILU(0) analysis/sweep/solve failed) so the process-wide fallback
    /// counters don't conflate the two.
    fn solve_krylov_with<R>(
        &self,
        sys: &SparseSys,
        run: impl Fn(&dyn krylov::Precond) -> Result<R>,
    ) -> KrylovAttempt<R> {
        let mut guard = self.factor_cache.0.lock().unwrap_or_else(|p| p.into_inner());
        match guard.as_mut() {
            Some(CacheState::Ready(entry))
                if entry.numeric.is_factored() && entry.numeric.symbolic().matches(sys) =>
            {
                // warm: the (possibly value-stale) complete LU — no
                // reassembly, no refactorization; on failure leave the
                // entry intact so the direct fallback can refactor it
                return match run(&entry.numeric) {
                    Ok(r) => {
                        PRECOND_REUSES.fetch_add(1, MemOrdering::Relaxed);
                        KrylovAttempt::Solved(r, true)
                    }
                    Err(_) => KrylovAttempt::WarmFailure,
                };
            }
            Some(CacheState::Ilu(pre)) if pre.dims_match(sys) => {
                // assemble performs the full pattern comparison; its Err
                // means the topology truly changed — rebuild below
                let swept = match pre.assemble(sys) {
                    Ok(true) => Some(true),
                    Ok(false) => Some(pre.factor().is_ok()),
                    Err(_) => None,
                };
                match swept {
                    Some(true) => {
                        return match run(&*pre) {
                            Ok(r) => {
                                PRECOND_REUSES.fetch_add(1, MemOrdering::Relaxed);
                                KrylovAttempt::Solved(r, true)
                            }
                            Err(_) => KrylovAttempt::WarmFailure,
                        };
                    }
                    // value-dependent breakdown: keep the analysis (the
                    // pattern is still valid — the next value set may
                    // sweep fine) and fall back to the direct engine
                    Some(false) => return KrylovAttempt::WarmFailure,
                    None => {}
                }
            }
            _ => {}
        }
        // cold: fresh pattern analysis + ILU(0) sweep. The analysis is
        // cached even when the numeric sweep or the solve fails — those
        // failures are value-dependent, and later solves must retry the
        // cheap sweep, not repeat the O(nnz) pattern analysis.
        let Ok(mut pre) = krylov::Ilu0::analyze(sys) else {
            return KrylovAttempt::ColdFailure;
        };
        let out = if pre.assemble(sys).is_err() || pre.factor().is_err() {
            None
        } else {
            run(&pre).ok()
        };
        *guard = Some(CacheState::Ilu(pre));
        match out {
            Some(r) => KrylovAttempt::Solved(r, false),
            None => KrylovAttempt::ColdFailure,
        }
    }

    /// One iterative solve of the stamped system (GMRES + cached
    /// preconditioner), residual-certified. `None` => use the direct path
    /// (the warm/cold fallback counter has already been bumped).
    fn solve_krylov(&self, sys: &SparseSys) -> Option<(Vec<f64>, solve::SolveStats)> {
        let cfg = self.solver.cfg();
        let kern = backend::resolve(self.backend);
        let run = |pre: &dyn krylov::Precond| -> Result<(Vec<f64>, solve::SolveStats)> {
            let (x, st) = krylov::gmres_kern(sys, &sys.b, pre, &cfg, kern)?;
            if !residual_ok(sys, &sys.b, &x) {
                bail!("krylov: converged solution failed the residual gate");
            }
            Ok((x, st))
        };
        match self.solve_krylov_with(sys, run) {
            KrylovAttempt::Solved((x, mut st), reused) => {
                st.precond_reused = reused;
                Some((x, st))
            }
            failure => {
                failure.count_fallback();
                None
            }
        }
    }

    /// Iterative multi-RHS solve: one shared preconditioner, Krylov sweeps
    /// pipelined across RHS columns over `workers` threads. `None` => use
    /// the direct path (fallback counter already bumped).
    fn solve_krylov_batch(
        &self,
        sys: &SparseSys,
        rhss: &[Vec<f64>],
        workers: usize,
    ) -> Option<Vec<Vec<f64>>> {
        let cfg = self.solver.cfg();
        let kern = backend::resolve(self.backend);
        let run = |pre: &dyn krylov::Precond| -> Result<Vec<Vec<f64>>> {
            let (xs, _st) = krylov::gmres_batch_kern(sys, rhss, pre, &cfg, workers, kern)?;
            if !xs.iter().zip(rhss).all(|(x, b)| residual_ok(sys, b, x)) {
                bail!("krylov: batch solution failed the residual gate");
            }
            Ok(xs)
        };
        match self.solve_krylov_with(sys, run) {
            KrylovAttempt::Solved(xs, _) => Some(xs),
            failure => {
                failure.count_fallback();
                None
            }
        }
    }

    /// Batched DC operating points over a fixed topology. Each batch entry
    /// is a list of `(vsource element index, volts)` overrides (see
    /// [`Circuit::vsource_index`]); entries are applied in order and the
    /// circuit is left holding the last entry's values.
    ///
    /// Linear circuits (no diodes/multipliers — i.e. crossbar reads) pay
    /// one factorization plus a single multi-RHS substitution pass for the
    /// whole batch (or, under an iterative [`krylov::SolverStrategy`], one
    /// shared preconditioner plus per-RHS GMRES sweeps); nonlinear
    /// circuits fall back to sequential (still symbolic-cached) Newton
    /// solves. Returns node-voltage vectors like [`Circuit::dc_op`].
    pub fn dc_op_batch(
        &mut self,
        overrides: &[Vec<(usize, f64)>],
        ordering: solve::Ordering,
    ) -> Result<Vec<Vec<f64>>> {
        self.dc_op_batch_par(overrides, ordering, 1)
    }

    /// [`Circuit::dc_op_batch`] with the iterative path's per-RHS Krylov
    /// sweeps distributed over `workers` threads (direct multi-RHS
    /// substitution is single-pass and ignores `workers`).
    pub fn dc_op_batch_par(
        &mut self,
        overrides: &[Vec<(usize, f64)>],
        ordering: solve::Ordering,
        workers: usize,
    ) -> Result<Vec<Vec<f64>>> {
        if overrides.is_empty() {
            return Ok(Vec::new());
        }
        let nonlinear = self
            .elements
            .iter()
            .any(|e| matches!(e, Element::Diode(..) | Element::Mult(..)));
        if nonlinear {
            return self.dc_op_batch_sequential(overrides, ordering);
        }

        let n_nodes = self.node_count();
        let dim = (n_nodes - 1) + self.num_branches();
        let v0 = vec![0.0; n_nodes];
        // the matrix of a linear MNA system is independent of source
        // values: stamp once, rebuild only the RHS per batch entry
        let sys = self.stamp(dim, n_nodes, &v0)?;
        let kern = backend::resolve(self.backend);
        // batched RHS assembly: V-source branch slots are uniquely owned
        // (I sources only touch node rows, which sit below the branch
        // block), so an override is a single-slot scatter onto the base
        // stamp — the backend builds each column from the previous one in
        // O(overrides) instead of re-walking the element list per entry
        let mut vsource_slot = vec![usize::MAX; self.elements.len()];
        let mut br = n_nodes - 1;
        for (k, e) in self.elements.iter().enumerate() {
            match e {
                Element::Vsource(..) => {
                    vsource_slot[k] = br;
                    br += 1;
                }
                Element::Vcvs(..) | Element::Mult(..) | Element::Inductor(..) => br += 1,
                _ => {}
            }
        }
        let base = self.stamp_rhs(dim, n_nodes);
        let mut slot_sets = Vec::with_capacity(overrides.len());
        for ov in overrides {
            let mut set = Vec::with_capacity(ov.len());
            for &(idx, v) in ov {
                // keeps the documented semantics: the circuit is left
                // holding the last entry's source values
                self.set_vsource_at(idx, v)?;
                set.push((vsource_slot[idx], v));
            }
            slot_sets.push(set);
        }
        let rhss = kern.rhs_columns(&base, &slot_sets);

        if self.solver.wants_iterative(sys.nnz()) {
            if let Some(xs) = self.solve_krylov_batch(&sys, &rhss, workers) {
                return Ok(xs
                    .into_iter()
                    .map(|x| {
                        let mut v_nodes = vec![0.0; n_nodes];
                        v_nodes[1..].copy_from_slice(&x[..n_nodes - 1]);
                        v_nodes
                    })
                    .collect());
            }
            // warm/cold fallback counter bumped inside solve_krylov_batch
        }

        let solved = {
            let mut guard = self.factor_cache.0.lock().unwrap_or_else(|p| p.into_inner());
            let mut ready = false;
            let mut known_unusable = false;
            match guard.as_mut() {
                Some(CacheState::Ready(entry)) if entry.ordering == ordering => {
                    if let Ok(unchanged) = entry.numeric.assemble(&sys) {
                        ready = unchanged || entry.numeric.refactor().is_ok();
                    }
                }
                Some(CacheState::Unusable { ordering: o, dim: d, nnz })
                    if *o == ordering && *d == sys.n && *nnz == sys.nnz() =>
                {
                    known_unusable = true;
                }
                _ => {}
            }
            if !ready && !known_unusable {
                if let Ok((_, numeric)) = factor::factor_solve(&sys, ordering) {
                    *guard = Some(CacheState::Ready(CacheEntry { ordering, numeric }));
                    ready = true;
                }
            }
            if ready {
                let Some(CacheState::Ready(entry)) = guard.as_ref() else {
                    unreachable!("entry just ensured");
                };
                match entry.numeric.solve_multi_kern(&rhss, kern) {
                    // certify every batch entry — a near-zero first RHS must
                    // not vacuously vouch for the rest of the batch
                    Ok(xs)
                        if xs
                            .iter()
                            .zip(&rhss)
                            .all(|(x, b)| residual_ok(&sys, b, x)) =>
                    {
                        Some(xs)
                    }
                    _ => None,
                }
            } else {
                None
            }
        };
        let xs = match solved {
            Some(xs) => xs,
            None => {
                // factored batch failed: sequential fallback (exact dc_op
                // semantics, including its own reference fallback)
                return self.dc_op_batch_sequential(overrides, ordering);
            }
        };
        Ok(xs
            .into_iter()
            .map(|x| {
                let mut v_nodes = vec![0.0; n_nodes];
                v_nodes[1..].copy_from_slice(&x[..n_nodes - 1]);
                v_nodes
            })
            .collect())
    }

    /// Per-entry batch fallback: apply each override set in turn and run a
    /// full (cached) `dc_op` — shared by the nonlinear and
    /// factored-failure paths of [`Circuit::dc_op_batch`].
    fn dc_op_batch_sequential(
        &mut self,
        overrides: &[Vec<(usize, f64)>],
        ordering: solve::Ordering,
    ) -> Result<Vec<Vec<f64>>> {
        let mut out = Vec::with_capacity(overrides.len());
        for ov in overrides {
            for &(idx, v) in ov {
                self.set_vsource_at(idx, v)?;
            }
            out.push(self.dc_op_with(ordering)?);
        }
        Ok(out)
    }

    /// RHS-only stamp for linear circuits: the `b` vector of the MNA system
    /// for the current element values (same branch walk as [`Circuit::stamp`]).
    fn stamp_rhs(&self, dim: usize, n_nodes: usize) -> Vec<f64> {
        let mut b = vec![0.0; dim];
        let idx = |node: usize| node.checked_sub(1); // ground (0) dropped
        let mut br = n_nodes - 1;
        for e in &self.elements {
            match *e {
                Element::Resistor(..)
                | Element::Diode(..)
                | Element::Capacitor(..)
                | Element::Vccs(..) => {}
                Element::Isource(_, a, k, amps) => {
                    if let Some(i) = idx(a) {
                        b[i] -= amps;
                    }
                    if let Some(j) = idx(k) {
                        b[j] += amps;
                    }
                }
                Element::Vsource(_, _, _, volts) => {
                    b[br] += volts;
                    br += 1;
                }
                Element::Vcvs(..) | Element::Mult(..) | Element::Inductor(..) => {
                    br += 1;
                }
            }
        }
        b
    }

    /// Build the MNA system around the current diode linearization point
    /// (DC view: capacitors open, inductors short).
    fn stamp(&self, dim: usize, n_nodes: usize, v_prev: &[f64]) -> Result<SparseSys> {
        self.stamp_dyn(dim, n_nodes, v_prev, 0.0, 0.0)
    }

    /// [`Circuit::stamp`] with companion-model coefficients for the dynamic
    /// elements: a capacitor contributes conductance `C·cap_g`, an inductor
    /// a branch self-term `-L·ind_g` (both `add_keep`-stamped so DC init at
    /// `cap_g = ind_g = 0` and every transient step at every `h` emit the
    /// identical pattern — see the module docs). The integrators in
    /// [`transient`] pick the coefficients (e.g. Backward Euler:
    /// `cap_g = ind_g = 1/h`).
    pub(crate) fn stamp_dyn(
        &self,
        dim: usize,
        n_nodes: usize,
        v_prev: &[f64],
        cap_g: f64,
        ind_g: f64,
    ) -> Result<SparseSys> {
        let mut sys = SparseSys::new(dim);
        // node index helper: ground (0) is dropped
        let idx = |node: usize| node.checked_sub(1);
        let mut br = n_nodes - 1; // branch current unknowns follow nodes

        for e in &self.elements {
            match *e {
                Element::Resistor(ref name, a, b, r) => {
                    if r <= 0.0 {
                        bail!("resistor {name} has non-positive value {r}");
                    }
                    let g = 1.0 / r;
                    if let Some(i) = idx(a) {
                        sys.add(i, i, g);
                    }
                    if let Some(j) = idx(b) {
                        sys.add(j, j, g);
                    }
                    if let (Some(i), Some(j)) = (idx(a), idx(b)) {
                        sys.add(i, j, -g);
                        sys.add(j, i, -g);
                    }
                }
                Element::Isource(_, a, b, amps) => {
                    if let Some(i) = idx(a) {
                        sys.add_b(i, -amps);
                    }
                    if let Some(j) = idx(b) {
                        sys.add_b(j, amps);
                    }
                }
                Element::Vsource(_, a, b, volts) => {
                    if let Some(i) = idx(a) {
                        sys.add(i, br, 1.0);
                        sys.add(br, i, 1.0);
                    }
                    if let Some(j) = idx(b) {
                        sys.add(j, br, -1.0);
                        sys.add(br, j, -1.0);
                    }
                    sys.add_b(br, volts);
                    br += 1;
                }
                Element::Vccs(_, op, om, cp, cm, gm) => {
                    // current gm*(v(cp) - v(cm)) flows op -> om: pure node
                    // stamps, no branch unknown — the transconductance
                    // analogue of a resistor between controlled ports
                    if let (Some(i), Some(k)) = (idx(op), idx(cp)) {
                        sys.add(i, k, gm);
                    }
                    if let (Some(i), Some(l)) = (idx(op), idx(cm)) {
                        sys.add(i, l, -gm);
                    }
                    if let (Some(j), Some(k)) = (idx(om), idx(cp)) {
                        sys.add(j, k, -gm);
                    }
                    if let (Some(j), Some(l)) = (idx(om), idx(cm)) {
                        sys.add(j, l, gm);
                    }
                }
                Element::Vcvs(_, op, om, cp, cm, gain) => {
                    // v(op) - v(om) = gain * (v(cp) - v(cm))
                    if let Some(i) = idx(op) {
                        sys.add(i, br, 1.0);
                        sys.add(br, i, 1.0);
                    }
                    if let Some(j) = idx(om) {
                        sys.add(j, br, -1.0);
                        sys.add(br, j, -1.0);
                    }
                    if let Some(i) = idx(cp) {
                        sys.add(br, i, -gain);
                    }
                    if let Some(j) = idx(cm) {
                        sys.add(br, j, gain);
                    }
                    br += 1;
                }
                Element::Mult(_, out, ca, cb2, gain) => {
                    // Newton linearization of V(out) = g*Va*Vb around
                    // (Va0, Vb0):  V(out) - g*Vb0*Va - g*Va0*Vb = -g*Va0*Vb0
                    // Control coefficients are zero at the initial operating
                    // point, so stamp them structurally (add_keep) to keep
                    // the pattern — and the cached factorization — stable
                    // across Newton iterations.
                    let va0 = v_prev[ca];
                    let vb0 = v_prev[cb2];
                    if let Some(i) = idx(out) {
                        sys.add(i, br, 1.0);
                        sys.add(br, i, 1.0);
                    }
                    if let Some(i) = idx(ca) {
                        sys.add_keep(br, i, -gain * vb0);
                    }
                    if let Some(j) = idx(cb2) {
                        sys.add_keep(br, j, -gain * va0);
                    }
                    sys.add_b(br, -gain * va0 * vb0);
                    br += 1;
                }
                Element::Capacitor(ref name, a, b, cap) => {
                    if cap <= 0.0 {
                        bail!("capacitor {name} has non-positive value {cap}");
                    }
                    // companion conductance; zero at DC, but the slots are
                    // reserved so the pattern never changes with h
                    let g = cap * cap_g;
                    if let Some(i) = idx(a) {
                        sys.add_keep(i, i, g);
                    }
                    if let Some(j) = idx(b) {
                        sys.add_keep(j, j, g);
                    }
                    if let (Some(i), Some(j)) = (idx(a), idx(b)) {
                        sys.add_keep(i, j, -g);
                        sys.add_keep(j, i, -g);
                    }
                }
                Element::Inductor(ref name, a, b, ind) => {
                    if ind <= 0.0 {
                        bail!("inductor {name} has non-positive value {ind}");
                    }
                    // branch row: v(a) - v(b) - L·ind_g·i = history (RHS);
                    // ind_g = 0 at DC makes it a short carrying i as an
                    // unknown, same pattern as every transient step
                    if let Some(i) = idx(a) {
                        sys.add(i, br, 1.0);
                        sys.add(br, i, 1.0);
                    }
                    if let Some(j) = idx(b) {
                        sys.add(j, br, -1.0);
                        sys.add(br, j, -1.0);
                    }
                    sys.add_keep(br, br, -ind * ind_g);
                    br += 1;
                }
                Element::Diode(_, a, k, isat, nvt) => {
                    // Newton companion: G_eq = dI/dV at v0, I_eq = I(v0) - G_eq*v0
                    let v0 = (v_prev[a] - v_prev[k]).clamp(-5.0, 0.9);
                    let ex = (v0 / nvt).exp();
                    let g_eq = (isat / nvt * ex).max(1e-12);
                    let i_eq = isat * (ex - 1.0) - g_eq * v0;
                    if let Some(i) = idx(a) {
                        sys.add(i, i, g_eq);
                        sys.add_b(i, -i_eq);
                    }
                    if let Some(j) = idx(k) {
                        sys.add(j, j, g_eq);
                        sys.add_b(j, i_eq);
                    }
                    if let (Some(i), Some(j)) = (idx(a), idx(k)) {
                        sys.add(i, j, -g_eq);
                        sys.add(j, i, -g_eq);
                    }
                }
            }
        }
        Ok(sys)
    }
}

/// Synthetic n-input, c-column ideal-TIA crossbar as one monolithic MNA
/// [`Circuit`] — bench/test scaffolding shared by the solver benches, the
/// Krylov integration tests and the property tests. Same shape the
/// netlist emitter produces for an FC layer (input V sources, memristor
/// resistors `r_base/g` with g in (0.05, 0.95), feedback `r_base/2`,
/// 1e6-gain TIA op-amps), stamped directly so giant sizes skip the
/// netlist-text round trip.
pub fn synthetic_crossbar_circuit(
    inputs: usize,
    cols: usize,
    r_base: f64,
    seed: u64,
) -> Circuit {
    let mut rng = crate::util::prng::Rng::new(seed);
    let mut c = Circuit::new("synthetic monolithic crossbar");
    let in_nodes: Vec<usize> = (0..inputs).map(|r| c.node(&format!("in{r}"))).collect();
    for (r, &node) in in_nodes.iter().enumerate() {
        c.vsource(&format!("V{r}"), node, 0, (r as f64 * 0.7).sin() * 0.3);
    }
    for col in 0..cols {
        let vcol = c.node(&format!("vcol{col}"));
        let vout = c.node(&format!("vout{col}"));
        for (r, &node) in in_nodes.iter().enumerate() {
            let g = 0.05 + 0.9 * rng.f64();
            c.resistor(&format!("RM{r}_{col}"), node, vcol, r_base / g);
        }
        c.resistor(&format!("RF{col}"), vcol, vout, r_base / 2.0);
        c.opamp(&format!("E{col}"), 0, vcol, vout);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn voltage_divider() {
        let mut c = Circuit::new("divider");
        let vin = c.node("in");
        let mid = c.node("mid");
        c.vsource("V1", vin, 0, 10.0);
        c.resistor("R1", vin, mid, 1000.0);
        c.resistor("R2", mid, 0, 1000.0);
        let v = c.dc_op().unwrap();
        assert!((v[mid] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn current_source_into_resistor() {
        let mut c = Circuit::new("ir");
        let n = c.node("n");
        c.isource("I1", 0, n, 1e-3); // 1 mA into n
        c.resistor("R1", n, 0, 2000.0);
        let v = c.dc_op().unwrap();
        assert!((v[n] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn inverting_tia() {
        // TIA: 1 V through 1k into virtual ground, Rf = 1k -> out = -1 V
        let mut c = Circuit::new("tia");
        let vin = c.node("in");
        let vminus = c.node("vm");
        let out = c.node("out");
        c.vsource("V1", vin, 0, 1.0);
        c.resistor("Rin", vin, vminus, 1000.0);
        c.resistor("Rf", vminus, out, 1000.0);
        c.opamp("X1", 0, vminus, out);
        let v = c.dc_op().unwrap();
        assert!((v[out] + 1.0).abs() < 1e-4, "out {}", v[out]);
        assert!(v[vminus].abs() < 1e-4, "virtual ground {}", v[vminus]);
    }

    #[test]
    fn summing_tia_two_inputs() {
        // two input branches into one virtual ground: out = -(v1*g1 + v2*g2)*Rf
        let mut c = Circuit::new("sum");
        let v1 = c.node("v1");
        let v2 = c.node("v2");
        let vm = c.node("vm");
        let out = c.node("out");
        c.vsource("V1", v1, 0, 0.5);
        c.vsource("V2", v2, 0, -0.25);
        c.resistor("R1", v1, vm, 1000.0);
        c.resistor("R2", v2, vm, 500.0);
        c.resistor("Rf", vm, out, 1000.0);
        c.opamp("X1", 0, vm, out);
        let v = c.dc_op().unwrap();
        let expect = -(0.5 / 1000.0 - 0.25 / 500.0) * 1000.0; // = 0.0
        assert!((v[out] - expect).abs() < 1e-4, "out {}", v[out]);
    }

    #[test]
    fn diode_forward_drop() {
        let mut c = Circuit::new("d");
        let vin = c.node("in");
        let mid = c.node("mid");
        c.vsource("V1", vin, 0, 5.0);
        c.resistor("R1", vin, mid, 1000.0);
        c.diode("D1", mid, 0);
        let v = c.dc_op().unwrap();
        assert!(v[mid] > 0.4 && v[mid] < 0.85, "diode drop {}", v[mid]);
    }

    #[test]
    fn diode_reverse_blocks() {
        let mut c = Circuit::new("dr");
        let vin = c.node("in");
        let mid = c.node("mid");
        c.vsource("V1", vin, 0, -5.0);
        c.resistor("R1", vin, mid, 1000.0);
        c.diode("D1", mid, 0);
        let v = c.dc_op().unwrap();
        assert!((v[mid] + 5.0).abs() < 0.01, "reverse diode should block: {}", v[mid]);
    }

    #[test]
    fn set_vsource_updates() {
        let mut c = Circuit::new("sv");
        let vin = c.node("in");
        c.vsource("V1", vin, 0, 1.0);
        c.resistor("R1", vin, 0, 100.0);
        assert!((c.dc_op().unwrap()[vin] - 1.0).abs() < 1e-12);
        c.set_vsource("V1", 3.0).unwrap();
        assert!((c.dc_op().unwrap()[vin] - 3.0).abs() < 1e-12);
        assert!(c.set_vsource("nope", 0.0).is_err());
    }

    #[test]
    fn negative_resistor_rejected() {
        let mut c = Circuit::new("bad");
        let n = c.node("n");
        c.vsource("V1", n, 0, 1.0);
        c.resistor("R1", n, 0, -5.0);
        assert!(c.dc_op().is_err());
    }

    fn crossbar_like(inputs: usize, cols: usize) -> Circuit {
        let mut c = Circuit::new("cached-vs-reference");
        let in_nodes: Vec<usize> =
            (0..inputs).map(|r| c.node(&format!("in{r}"))).collect();
        for (r, &node) in in_nodes.iter().enumerate() {
            c.vsource(&format!("V{r}"), node, 0, (r as f64 * 0.7).sin() * 0.3);
        }
        for col in 0..cols {
            let vcol = c.node(&format!("vcol{col}"));
            let vout = c.node(&format!("vout{col}"));
            for (r, &node) in in_nodes.iter().enumerate() {
                c.resistor(&format!("RM{r}_{col}"), node, vcol, 100.0 * (2 + (r + col) % 7) as f64);
            }
            c.resistor(&format!("RF{col}"), vcol, vout, 50.0);
            c.opamp(&format!("E{col}"), 0, vcol, vout);
        }
        c
    }

    #[test]
    fn cached_sweep_matches_reference_solves() {
        // factor-once/solve-many across set_vsource edits must agree with
        // per-call reference elimination within 1e-9
        let mut c = crossbar_like(24, 6);
        let idxs: Vec<usize> =
            (0..24).map(|r| c.vsource_index(&format!("V{r}")).unwrap()).collect();
        for sweep in 0..5 {
            for (r, &i) in idxs.iter().enumerate() {
                c.set_vsource_at(i, ((r + sweep) as f64 * 0.31).cos() * 0.4).unwrap();
            }
            let cached = c.dc_op().unwrap();
            let (reference, _) = c.dc_op_stats_reference(solve::Ordering::Smart).unwrap();
            for (a, b) in cached.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-9, "sweep {sweep}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn dc_op_batch_matches_sequential() {
        let mut c = crossbar_like(12, 4);
        let idxs: Vec<usize> =
            (0..12).map(|r| c.vsource_index(&format!("V{r}")).unwrap()).collect();
        let batches: Vec<Vec<(usize, f64)>> = (0..4)
            .map(|k| {
                idxs.iter()
                    .enumerate()
                    .map(|(r, &i)| (i, ((r * 3 + k) as f64 * 0.17).sin() * 0.5))
                    .collect()
            })
            .collect();
        let batched = c.clone().dc_op_batch(&batches, solve::Ordering::Smart).unwrap();
        assert_eq!(batched.len(), 4);
        for (k, ov) in batches.iter().enumerate() {
            for &(i, v) in ov {
                c.set_vsource_at(i, v).unwrap();
            }
            let seq = c.dc_op().unwrap();
            for (a, b) in batched[k].iter().zip(&seq) {
                assert!((a - b).abs() < 1e-9, "batch {k}");
            }
        }
    }

    #[test]
    fn dc_op_batch_nonlinear_falls_back() {
        // diode clamp: batch must agree with per-point Newton solves
        let mut c = Circuit::new("batch-diode");
        let vin = c.node("in");
        let mid = c.node("mid");
        c.vsource("V1", vin, 0, 0.0);
        c.resistor("R1", vin, mid, 1000.0);
        c.diode("D1", mid, 0);
        let vi = c.vsource_index("V1").unwrap();
        let batches: Vec<Vec<(usize, f64)>> =
            vec![vec![(vi, -2.0)], vec![(vi, 0.5)], vec![(vi, 5.0)]];
        let out = c.clone().dc_op_batch(&batches, solve::Ordering::Smart).unwrap();
        for (k, ov) in batches.iter().enumerate() {
            c.set_vsource_at(ov[0].0, ov[0].1).unwrap();
            let seq = c.dc_op().unwrap();
            assert!((out[k][mid] - seq[mid]).abs() < 1e-9, "point {k}");
        }
    }

    #[test]
    fn iterative_solver_matches_direct_on_crossbar() {
        let mut c = crossbar_like(24, 6);
        c.set_solver(krylov::SolverStrategy::Iterative {
            restart: 16,
            tol: 1e-11,
            max_iter: 400,
        });
        let idxs: Vec<usize> =
            (0..24).map(|r| c.vsource_index(&format!("V{r}")).unwrap()).collect();
        for sweep in 0..3 {
            for (r, &i) in idxs.iter().enumerate() {
                c.set_vsource_at(i, ((r + sweep) as f64 * 0.29).sin() * 0.4).unwrap();
            }
            let (x, st) = c.dc_op_stats(solve::Ordering::Smart).unwrap();
            assert!(st.iterations > 0, "iterative path must have run");
            assert_eq!(st.precond_reused, sweep > 0, "ILU pattern cached after sweep 0");
            let (reference, _) = c.dc_op_stats_reference(solve::Ordering::Smart).unwrap();
            for (a, b) in x.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-6, "sweep {sweep}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn warm_gmres_reuses_cached_lu_after_value_drift() {
        // factor once directly, drift memristor values, then iterative
        // re-solves must converge off the stale LU with no refactorization
        let mut c = crossbar_like(16, 4);
        c.set_solver(krylov::SolverStrategy::Direct);
        let (_, st0) = c.dc_op_stats(solve::Ordering::Smart).unwrap();
        assert_eq!(st0.iterations, 0);
        for e in c.elements.iter_mut() {
            if let Element::Resistor(name, _, _, r) = e {
                if name.starts_with("RM") {
                    *r *= 1.02; // programming-noise-style value drift
                }
            }
        }
        c.set_solver(krylov::SolverStrategy::Iterative {
            restart: 16,
            tol: 1e-11,
            max_iter: 400,
        });
        let (x, st) = c.dc_op_stats(solve::Ordering::Smart).unwrap();
        assert!(st.precond_reused, "stale complete LU must serve as preconditioner");
        assert!(st.iterations > 0 && st.iterations <= 16, "handful of iterations");
        let (reference, _) = c.dc_op_stats_reference(solve::Ordering::Smart).unwrap();
        for (a, b) in x.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn iterative_batch_matches_sequential() {
        let mut c = crossbar_like(12, 4);
        c.set_solver(krylov::SolverStrategy::Iterative {
            restart: 16,
            tol: 1e-11,
            max_iter: 400,
        });
        let idxs: Vec<usize> =
            (0..12).map(|r| c.vsource_index(&format!("V{r}")).unwrap()).collect();
        let batches: Vec<Vec<(usize, f64)>> = (0..4)
            .map(|k| {
                idxs.iter()
                    .enumerate()
                    .map(|(r, &i)| (i, ((r * 5 + k) as f64 * 0.19).sin() * 0.5))
                    .collect()
            })
            .collect();
        let batched =
            c.clone().dc_op_batch_par(&batches, solve::Ordering::Smart, 2).unwrap();
        for (k, ov) in batches.iter().enumerate() {
            for &(i, v) in ov {
                c.set_vsource_at(i, v).unwrap();
            }
            let (seq, _) = c.dc_op_stats_reference(solve::Ordering::Smart).unwrap();
            for (a, b) in batched[k].iter().zip(&seq) {
                assert!((a - b).abs() < 1e-6, "batch {k}");
            }
        }
    }

    #[test]
    fn unconvergeable_iterative_config_falls_back_to_direct() {
        // max_iter 0 can never converge: the solve must silently take the
        // direct path and stay exact (no panic, no error)
        let mut c = crossbar_like(10, 3);
        c.set_solver(krylov::SolverStrategy::Iterative {
            restart: 4,
            tol: 1e-15,
            max_iter: 0,
        });
        let (x, st) = c.dc_op_stats(solve::Ordering::Smart).unwrap();
        assert_eq!(st.iterations, 0, "fallback solve is direct");
        let (reference, _) = c.dc_op_stats_reference(solve::Ordering::Smart).unwrap();
        for (a, b) in x.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn set_vsource_at_and_index() {
        let mut c = Circuit::new("svi");
        let vin = c.node("in");
        c.resistor("R1", vin, 0, 100.0);
        c.vsource("V1", vin, 0, 1.0);
        let i = c.vsource_index("V1").unwrap();
        c.set_vsource_at(i, 2.5).unwrap();
        assert!((c.dc_op().unwrap()[vin] - 2.5).abs() < 1e-12);
        assert!(c.vsource_index("nope").is_none());
        assert!(c.set_vsource_at(0, 0.0).is_err()); // element 0 is a resistor
    }

    #[test]
    fn topology_edit_invalidates_cache() {
        // growing the circuit after a solve must re-analyze, not mis-solve
        let mut c = Circuit::new("grow");
        let a = c.node("a");
        c.vsource("V1", a, 0, 2.0);
        c.resistor("R1", a, 0, 100.0);
        assert!((c.dc_op().unwrap()[a] - 2.0).abs() < 1e-12);
        let b = c.node("b");
        c.resistor("R2", a, b, 100.0);
        c.resistor("R3", b, 0, 100.0);
        let v = c.dc_op().unwrap();
        assert!((v[b] - 1.0).abs() < 1e-12, "divider after growth: {}", v[b]);
    }

    #[test]
    fn larger_sparse_path() {
        // >220 unknowns forces the sparse backend: chain of dividers
        let mut c = Circuit::new("chain");
        let mut prev = c.node("in");
        c.vsource("V1", prev, 0, 1.0);
        for i in 0..300 {
            let nxt = c.node(&format!("n{i}"));
            c.resistor(&format!("Ra{i}"), prev, nxt, 100.0);
            c.resistor(&format!("Rb{i}"), nxt, 0, 1e6);
            prev = nxt;
        }
        let v = c.dc_op().unwrap();
        // RC-less transmission line: voltage decays monotonically along the
        // ladder and stays strictly positive
        let first = c.node_named("n0").unwrap();
        let mid = c.node_named("n150").unwrap();
        let last = c.node_named("n299").unwrap();
        assert!(v[first] > v[mid] && v[mid] > v[last], "non-monotone ladder");
        assert!(v[last] > 0.0 && v[first] < 1.0, "ladder end {}", v[last]);
    }
}
