//! Serving metrics: request counters, latency histogram, throughput.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Fixed log-scale latency histogram from 1 µs to ~67 s.
const BUCKETS: usize = 27;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    lat: Mutex<Hist>,
    queue_lat: Mutex<Hist>,
}

#[derive(Default, Clone)]
struct Hist {
    counts: [u64; BUCKETS],
    sum_us: u128,
    max_us: u64,
    n: u64,
}

impl Hist {
    fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.counts[b] += 1;
        self.sum_us += us as u128;
        self.max_us = self.max_us.max(us);
        self.n += 1;
    }

    fn quantile(&self, q: f64) -> Duration {
        if self.n == 0 {
            return Duration::ZERO;
        }
        let target = (self.n as f64 * q).ceil() as u64;
        let mut acc = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                // upper edge of bucket b
                return Duration::from_micros(1u64 << (b + 1));
            }
        }
        Duration::from_micros(self.max_us)
    }

    fn mean(&self) -> Duration {
        if self.n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.sum_us / self.n as u128) as u64)
    }
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub completed: u64,
    pub errors: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub lat_mean: Duration,
    pub lat_p50: Duration,
    pub lat_p95: Duration,
    pub lat_p99: Duration,
    pub lat_max: Duration,
    pub queue_mean: Duration,
}

impl Metrics {
    pub fn record_latency(&self, d: Duration) {
        self.lat.lock().unwrap().record(d);
    }

    pub fn record_queue(&self, d: Duration) {
        self.queue_lat.lock().unwrap().record(d);
    }

    pub fn snapshot(&self) -> Snapshot {
        let lat = self.lat.lock().unwrap().clone();
        let q = self.queue_lat.lock().unwrap().clone();
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            lat_mean: lat.mean(),
            lat_p50: lat.quantile(0.50),
            lat_p95: lat.quantile(0.95),
            lat_p99: lat.quantile(0.99),
            lat_max: Duration::from_micros(lat.max_us),
            queue_mean: q.mean(),
        }
    }
}

impl Snapshot {
    pub fn print(&self, wall: Duration) {
        let thr = self.completed as f64 / wall.as_secs_f64().max(1e-9);
        println!("  requests      {}", self.requests);
        println!("  completed     {}", self.completed);
        println!("  errors        {}", self.errors);
        println!("  batches       {} (padded slots {})", self.batches, self.padded_slots);
        println!("  throughput    {thr:.1} img/s");
        println!(
            "  latency       mean {:?}  p50 {:?}  p95 {:?}  p99 {:?}  max {:?}",
            self.lat_mean, self.lat_p50, self.lat_p95, self.lat_p99, self.lat_max
        );
        println!("  queue wait    mean {:?}", self.queue_mean);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let m = Metrics::default();
        for i in 1..=1000u64 {
            m.record_latency(Duration::from_micros(i * 10));
        }
        let s = m.snapshot();
        assert!(s.lat_p50 <= s.lat_p95);
        assert!(s.lat_p95 <= s.lat_p99);
        assert!(s.lat_p99 <= Duration::from_micros(s.lat_max.as_micros() as u64 * 2));
        assert!(s.lat_mean > Duration::ZERO);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.lat_mean, Duration::ZERO);
        assert_eq!(s.lat_p99, Duration::ZERO);
    }
}
