//! Analog circuit modules — transistor-level models of the paper's §3.4
//! activation circuits (Fig 4) plus fast behavioural equivalents.
//!
//! The circuit builders produce real [`Circuit`]s (op-amp adders /
//! dividers, diode+source limiters, a Gilbert-cell multiplier abstraction);
//! `sweep` reproduces Fig 4(c)/(d). The behavioural functions are the
//! rail-clipped piecewise forms the L2 JAX model uses — tests pin the SPICE
//! curves to them within the diode-knee tolerance.

use anyhow::{anyhow, Result};

use crate::spice::Circuit;

/// Software hard sigmoid: relu6(x + 3) / 6.
pub fn hard_sigmoid_sw(x: f64) -> f64 {
    ((x + 3.0) / 6.0).clamp(0.0, 1.0)
}

/// Software hard swish.
pub fn hard_swish_sw(x: f64) -> f64 {
    x * hard_sigmoid_sw(x)
}

/// Behavioural analog hard sigmoid (rail-limited input — ref.py mirror).
pub fn hard_sigmoid_analog(x: f64, v_rail: f64) -> f64 {
    hard_sigmoid_sw(x.clamp(-v_rail, v_rail))
}

/// Behavioural analog hard swish.
pub fn hard_swish_analog(x: f64, v_rail: f64) -> f64 {
    let x = x.clamp(-v_rail, v_rail);
    (x * hard_sigmoid_analog(x, v_rail)).clamp(-v_rail, v_rail)
}

/// Behavioural analog ReLU (CMOS, rail-limited).
pub fn relu_analog(x: f64, v_rail: f64) -> f64 {
    x.clamp(0.0, v_rail)
}

/// A built activation circuit: drive `vin_name`, read `out_node`.
/// Cloning clones the circuit including its cached factorization, so clones
/// can solve independently (e.g. one per worker thread).
#[derive(Clone)]
pub struct ActCircuit {
    pub circuit: Circuit,
    pub vin_name: String,
    pub out_node: String,
}

impl ActCircuit {
    /// Evaluate the circuit at one input voltage.
    ///
    /// Repeated calls reuse the circuit's cached factorization: the input
    /// source edit is RHS-only, so each Newton iteration replays the
    /// symbolic analysis computed on the first solve instead of
    /// re-eliminating from scratch (see [`crate::spice::factor`]).
    pub fn eval(&mut self, vin: f64) -> Result<f64> {
        self.circuit.set_vsource(&self.vin_name, vin)?;
        let sol = self.circuit.dc_op()?;
        let n = self
            .circuit
            .node_named(&self.out_node)
            .ok_or_else(|| anyhow!("no node {}", self.out_node))?;
        Ok(sol[n])
    }

    /// Input sweep — the Fig 4(c)/(d) curves. Factor-once/solve-many:
    /// every point after the first is a cached re-solve.
    pub fn sweep(&mut self, lo: f64, hi: f64, points: usize) -> Result<Vec<(f64, f64)>> {
        (0..points)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / (points - 1).max(1) as f64;
                Ok((x, self.eval(x)?))
            })
            .collect()
    }
}

/// Fig 4(a): hard sigmoid.
///
/// Stage 1 — inverting summing amplifier: out1 = -(x + 3)/6
///   (x through 60k, +3 V reference through 60k, Rf = 10k).
/// Stage 2 — unity inverter: hs_lin = (x + 3)/6.
/// Stage 3 — diode+source limiter (the paper's "max" operation):
///   clamp to [0, 1] with compensated clamp sources.
pub fn build_hard_sigmoid() -> ActCircuit {
    let mut c = Circuit::new("hard_sigmoid (Fig 4a)");
    let vin = c.node("vin");
    let vref = c.node("vref3");
    let sum_m = c.node("sum_vm");
    let out1 = c.node("out1");
    let inv_m = c.node("inv_vm");
    let out2 = c.node("out2");
    let lim = c.node("vout");

    c.vsource("VIN", vin, 0, 0.0);
    c.vsource("VREF", vref, 0, 3.0);
    // summing amp: Rf/Rin = 10k/60k = 1/6
    c.resistor("R1", vin, sum_m, 60_000.0);
    c.resistor("R2", vref, sum_m, 60_000.0);
    c.resistor("RF1", sum_m, out1, 10_000.0);
    c.opamp("EOP1", 0, sum_m, out1);
    // unity inverter
    c.resistor("R3", out1, inv_m, 10_000.0);
    c.resistor("RF2", inv_m, out2, 10_000.0);
    c.opamp("EOP2", 0, inv_m, out2);
    // limiter: series resistor then clamp diodes with compensating sources
    c.resistor("RS", out2, lim, 1_000.0);
    // low clamp at ~0 V: anode driven at +0.55 V so conduction starts when
    // the output node dips below ≈ -0.05 V (0.6 V knee compensated)
    let lo = c.node("vclamp_lo");
    c.vsource("VCLO", lo, 0, 0.55);
    c.diode("DLO", lo, lim);
    // high clamp at ~1 V: cathode at 1 - 0.55
    let hi = c.node("vclamp_hi");
    c.vsource("VCHI", hi, 0, 0.45);
    c.diode("DHI", lim, hi);
    ActCircuit { circuit: c, vin_name: "VIN".into(), out_node: "vout".into() }
}

/// Fig 4(b): hard swish = multiplier(x, hard_sigmoid(x)).
pub fn build_hard_swish() -> ActCircuit {
    // extend the hard-sigmoid front end's circuit in place (no moved-out
    // intermediate ActCircuit holding an emptied sentinel)
    let ActCircuit { mut circuit, .. } = build_hard_sigmoid();
    let vin = circuit.node("vin");
    let hs = circuit.node("vout");
    let out = circuit.node("vswish");
    circuit.mult("XMUL", out, vin, hs, 1.0);
    ActCircuit { circuit, vin_name: "VIN".into(), out_node: "vswish".into() }
}

/// Knee width of the diode limiter — tolerance band used when pinning the
/// SPICE curves to the piecewise software model.
pub const KNEE_TOL: f64 = 0.12;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn behavioural_matches_software_inside_rails() {
        for i in -50..=50 {
            let x = i as f64 / 10.0;
            if x.abs() < 7.9 {
                assert!((hard_swish_analog(x, 8.0) - hard_swish_sw(x)).abs() < 1e-12);
            }
        }
        assert_eq!(relu_analog(-2.0, 8.0), 0.0);
        assert_eq!(relu_analog(12.0, 8.0), 8.0);
    }

    #[test]
    fn spice_hard_sigmoid_linear_region() {
        let mut hs = build_hard_sigmoid();
        for x in [-2.0, -1.0, 0.0, 1.0, 2.0] {
            let y = hs.eval(x).unwrap();
            let want = hard_sigmoid_sw(x);
            assert!((y - want).abs() < 0.02, "x={x}: spice {y} vs sw {want}");
        }
    }

    #[test]
    fn spice_hard_sigmoid_saturates() {
        let mut hs = build_hard_sigmoid();
        let y_lo = hs.eval(-6.0).unwrap();
        let y_hi = hs.eval(6.0).unwrap();
        assert!(y_lo.abs() < KNEE_TOL, "low clamp {y_lo}");
        assert!((y_hi - 1.0).abs() < KNEE_TOL, "high clamp {y_hi}");
    }

    #[test]
    fn spice_hard_sigmoid_monotone() {
        let mut hs = build_hard_sigmoid();
        let curve = hs.sweep(-5.0, 5.0, 41).unwrap();
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-6, "non-monotone at {:?}", w);
        }
    }

    #[test]
    fn spice_hard_swish_matches_software() {
        let mut hw = build_hard_swish();
        for x in [-4.0, -2.0, -1.0, 0.0, 0.5, 1.0, 2.0, 4.0] {
            let y = hw.eval(x).unwrap();
            let want = hard_swish_sw(x);
            assert!(
                (y - want).abs() < KNEE_TOL + 0.02 * x.abs(),
                "x={x}: spice {y} vs sw {want}"
            );
        }
    }

    #[test]
    fn sweep_cache_matches_cold_solves() {
        // the cached sweep (one ActCircuit reused across points) must match
        // cold solves (a freshly built circuit per point) within 1e-9 —
        // the factor-once/solve-many equivalence guarantee
        for swish in [false, true] {
            let mut warm = if swish { build_hard_swish() } else { build_hard_sigmoid() };
            let curve = warm.sweep(-4.0, 4.0, 33).unwrap();
            for &(x, y) in &curve {
                let mut cold = if swish { build_hard_swish() } else { build_hard_sigmoid() };
                let y_cold = cold.eval(x).unwrap();
                assert!(
                    (y - y_cold).abs() < 1e-9,
                    "swish={swish} x={x}: cached {y} vs cold {y_cold}"
                );
            }
        }
    }

    #[test]
    fn sweep_covers_range() {
        let mut hs = build_hard_sigmoid();
        let curve = hs.sweep(-4.0, 4.0, 17).unwrap();
        assert_eq!(curve.len(), 17);
        assert_eq!(curve[0].0, -4.0);
        assert_eq!(curve[16].0, 4.0);
    }
}
