//! Crossbar microbenchmark — behavioural VMM throughput and SPICE solve
//! cost per crossbar size (supports the §Perf L3 iteration log), plus the
//! monolithic direct-vs-GMRES sweep for `spice::krylov`: one MNA system
//! per crossbar (no segmentation) solved by the direct factor engine and
//! by ILU(0)-preconditioned GMRES, up to the paper's 2050x1024 case and a
//! beyond-paper 4096x2048 point, appending the peak-resident-entries
//! proxy per strategy to BENCH_spice.json.
//!
//!   cargo bench --bench bench_crossbar
//!
//! `MEMX_BENCH_QUICK=1` runs the reduced CI smoke variant: one small
//! behavioural/seg64 size plus one monolithic iterative-vs-direct
//! comparison at 512x256.

use std::time::Instant;

use memx::mapper::{self, MapMode};
use memx::netlist;
use memx::nn::DeviceJson;
use memx::spice::krylov::SolverStrategy;
use memx::spice::solve::Ordering;
use memx::spice::{synthetic_crossbar_circuit, Circuit};
use memx::util::bench::{append_json_report, black_box, Bench};
use memx::util::pool;

fn device() -> DeviceJson {
    DeviceJson {
        r_on: 100.0,
        r_off: 16000.0,
        levels: 64,
        prog_sigma: 0.01,
        v_in: 2.5e-3,
        v_rail: 24.0,
        t_mem: 1e-10,
        slew_rate: 1e7,
        v_swing: 5.0,
        p_opamp: 1e-3,
        p_memristor: 1.1e-6,
        p_aux: 5e-4,
        t_opamp: 5e-7,
    }
}

fn main() {
    let quick = std::env::var("MEMX_BENCH_QUICK").is_ok();
    let dev = device();
    let mut b = if quick { Bench::quick() } else { Bench::default() };
    let mut derived: Vec<(String, f64)> = Vec::new();

    let seg_sizes: &[usize] = if quick { &[64] } else { &[64, 256, 512] };
    for &n in seg_sizes {
        let cb = mapper::build_synthetic_fc(n, n, 64, MapMode::Inverted, 5);
        let inputs: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.31).sin() * 0.4).collect();

        let s = b.run(&format!("eval_ideal {n}x{n}"), || {
            black_box(cb.eval_ideal(&inputs));
        });
        let macs = cb.devices.len() as f64;
        println!("    -> {:.1} M device-ops/s", macs / s.mean_secs() / 1e6);

        let segs = netlist::plan_segments(cb.cols, 64);
        let cold = b.run(&format!("spice seg64 {n}x{n} (emit+parse+solve all)"), || {
            for seg in &segs {
                let text = netlist::emit_crossbar(&cb, &dev, seg, Some(&inputs), segs.len());
                let c = netlist::parse(&text).unwrap();
                black_box(
                    netlist::solve_segment_outputs(&c, seg, true, Ordering::Smart).unwrap(),
                );
            }
        });

        // factor-once/solve-many: same read served from cached per-segment
        // LU factorizations, new inputs every iteration (RHS-only re-solves)
        let workers = pool::default_workers();
        let mut sim = cb.sim(&dev, 64, Ordering::Smart, SolverStrategy::Auto).unwrap();
        let mut k = 0usize;
        let warm = b.run(&format!("spice seg64 {n}x{n} cached resolve"), || {
            k += 1;
            let v: Vec<f64> =
                (0..n).map(|i| ((i + k) as f64 * 0.31).sin() * 0.4).collect();
            black_box(sim.solve_par(&v, workers).unwrap());
        });
        let speedup = cold.median.as_secs_f64() / warm.median.as_secs_f64().max(1e-12);
        println!("    -> cached-resolve median speedup {speedup:.1}x");
        derived.push((format!("seg64_{n}x{n}_cold_vs_cached"), speedup));
    }

    // --- monolithic sweep: direct factor vs GMRES cold/warm -------------
    // One MNA system per size (no segmentation). Cold = first solve
    // (analysis + factor/ILU); warm = RHS-only re-reads off the cached
    // engine state. Direct is skipped beyond the paper's 2050x1024 — the
    // memory-bound regime the iterative path exists for.
    let mono_sizes: &[(usize, usize)] = if quick {
        &[(512, 256)]
    } else {
        &[(512, 256), (1024, 512), (2050, 1024), (4096, 2048)]
    };
    let iterative = SolverStrategy::Iterative { restart: 24, tol: 1e-11, max_iter: 600 };
    for &(inputs, cols) in mono_sizes {
        let tag = format!("mono_{inputs}x{cols}");
        let seed = 77 ^ (inputs as u64);
        let bump = |c: &mut Circuit, vidx: &[usize], k: usize| {
            for (r, &i) in vidx.iter().enumerate() {
                c.set_vsource_at(i, ((r * 7 + k) as f64 * 0.13).sin() * 0.3).unwrap();
            }
        };

        // GMRES cold + warm
        let mut gc = synthetic_crossbar_circuit(inputs, cols, 100.0, seed);
        gc.set_solver(iterative);
        let vidx: Vec<usize> =
            (0..inputs).map(|r| gc.vsource_index(&format!("V{r}")).unwrap()).collect();
        let t0 = Instant::now();
        let (_, cold_st) = gc.dc_op_stats(Ordering::Smart).unwrap();
        b.record_once(&format!("{tag} gmres cold (ilu0 analysis+solve)"), t0.elapsed());
        let mut k = 0usize;
        let mut warm_iters = 0usize;
        let warm = b.run(&format!("{tag} gmres warm re-read"), || {
            k += 1;
            bump(&mut gc, &vidx, k);
            let (x, st) = gc.dc_op_stats(Ordering::Smart).unwrap();
            warm_iters += st.iterations;
            black_box(x);
        });
        println!(
            "    -> gmres: peak {} entries, cold {} iters, warm {:.1} iters/read",
            cold_st.peak_entries,
            cold_st.iterations,
            warm_iters as f64 / warm.iters.max(1) as f64
        );
        derived.push((format!("{tag}_peak_entries_gmres"), cold_st.peak_entries as f64));
        derived.push((format!("{tag}_gmres_cold_iters"), cold_st.iterations as f64));
        derived.push((format!("{tag}_gmres_relres"), cold_st.residual));

        // direct factor (reference memory/time point)
        if inputs * cols <= 2050 * 1024 {
            let mut dc = synthetic_crossbar_circuit(inputs, cols, 100.0, seed);
            dc.set_solver(SolverStrategy::Direct);
            let t0 = Instant::now();
            let (_, dst) = dc.dc_op_stats(Ordering::Smart).unwrap();
            b.record_once(&format!("{tag} direct cold (analysis+factor)"), t0.elapsed());
            let mut k = 0usize;
            b.run(&format!("{tag} direct warm re-read"), || {
                k += 1;
                bump(&mut dc, &vidx, k);
                black_box(dc.dc_op().unwrap());
            });
            let ratio = dst.peak_entries as f64 / cold_st.peak_entries.max(1) as f64;
            println!(
                "    -> direct: peak {} entries ({ratio:.2}x the gmres footprint)",
                dst.peak_entries
            );
            derived.push((format!("{tag}_peak_entries_direct"), dst.peak_entries as f64));
            derived.push((format!("{tag}_peak_direct_over_gmres"), ratio));
        } else {
            println!("    -> direct factorization skipped beyond the paper scale (memory)");
        }
    }

    b.table("crossbar microbenchmarks");
    if let Err(e) = append_json_report("BENCH_spice.json", "bench_crossbar", &b.rows, &derived) {
        eprintln!("warning: could not write BENCH_spice.json: {e}");
    }
}
