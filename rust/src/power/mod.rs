//! Latency (Eq 17) and energy (Eq 18) analytical models — Fig 8.
//!
//!   T_i = (T_m + T_o) * N_m + T_r                               (Eq 17)
//!   W_i = Σ U_max² G_max * T_m + P_o * T_o + P_r * T_r          (Eq 18)
//!
//! where N_m counts memristor-crossbar stages on the sequential path, T_o is
//! the op-amp transition time (swing / slew-rate), and T_r collects the
//! CMOS activation / adder / multiplier stages. Baseline constants (RTX 4090
//! 0.1654 ms, i7-12700 3.3924 ms — paper §5.2) are carried alongside the
//! digital-PJRT latency *measured on this host* so Fig 8 shows both.

use crate::mapper::{MapMode, MappedNetwork};
use crate::nn::DeviceJson;
use crate::pipeline::StageCoverage;

/// Latency of non-memristor stages per layer type (paper's T_r: existing
/// CMOS device data — activation, adder, multiplier each ~ns scale; the
/// dominant term stays the op-amp slew).
pub const T_ACT: f64 = 5e-9; // activation module settle
pub const T_ADD: f64 = 2e-9; // residual adder
pub const T_MUL: f64 = 5e-9; // SE channel multiplier

/// Paper §5.2 baseline constants (seconds).
pub const T_GPU_RTX4090: f64 = 0.1654e-3;
pub const T_CPU_I7_12700: f64 = 3.3924e-3;
/// Paper §5.3 energy baselines (joules per inference), back-derived from
/// the reported 4.5x / 61.7x savings over the 2.2 mJ analog inference.
pub const E_ANALOG_PAPER: f64 = 2.2e-3;
pub const E_GPU_RTX4090: f64 = 4.5 * E_ANALOG_PAPER;
pub const E_CPU_I7_12700: f64 = 61.7 * E_ANALOG_PAPER;

#[derive(Debug, Clone)]
pub struct LatencyBreakdown {
    /// memristor stages on the critical path (N_m)
    pub n_m: usize,
    /// per-stage crossbar response (T_m)
    pub t_mem: f64,
    /// per-stage op-amp transition (T_o)
    pub t_opamp: f64,
    /// other layers (T_r)
    pub t_rest: f64,
    /// total inference latency (T_i)
    pub total: f64,
}

/// Eq 17 over a mapped network.
pub fn latency(net: &MappedNetwork, dev: &DeviceJson) -> LatencyBreakdown {
    let n_m = net.memristor_stages();
    // T_o doubles in the conventional dual-op-amp mapping: two sequential
    // op-amp transitions per crossbar stage (§5.2's "1.30 µs" comparison).
    let t_o = dev.t_opamp * net.mode.opamps_per_port() as f64;
    let t_rest: f64 = net.layers.iter().map(|l| t_rest_of(l.kind)).sum();
    let total = (dev.t_mem + t_o) * n_m as f64 + t_rest;
    LatencyBreakdown { n_m, t_mem: dev.t_mem, t_opamp: t_o, t_rest, total }
}

/// Steady-state *pipelined* latency: with every crossbar stage holding its
/// own op-amps, stages overlap across a stream of frames and the per-frame
/// latency collapses to one crossbar+TIA settle plus the slowest CMOS stage.
/// This is the operating point the paper's §5.2 "as low as 1.24 µs" figure
/// corresponds to — its Eq 17 with N_m ≈ 100 sequential stages would give
/// ~50 µs, inconsistent with its own headline (see EXPERIMENTS.md E5 note).
pub fn latency_pipelined(net: &MappedNetwork, dev: &DeviceJson) -> LatencyBreakdown {
    let t_o = dev.t_opamp * net.mode.opamps_per_port() as f64;
    let t_rest = T_ACT + T_MUL; // slowest CMOS stage in flight
    let total = dev.t_mem + t_o + t_rest;
    LatencyBreakdown { n_m: 1, t_mem: dev.t_mem, t_opamp: t_o, t_rest, total }
}

/// T_r contribution of one stage kind (CMOS activation / adder /
/// multiplier constants) — shared by the mapper-based [`latency`] and the
/// stage-hook [`latency_coverage`]. The composite SE stage folds its
/// branch ReLU + hard sigmoid + channel multiplier.
fn t_rest_of(kind: &str) -> f64 {
    match kind {
        "HSwish" => T_ACT + T_MUL,
        "HSigmoid" | "ReLU" => T_ACT,
        "SE" => 2.0 * T_ACT + T_MUL,
        "Add" => T_ADD,
        _ => 0.0,
    }
}

/// Eq 17 over a compiled pipeline's per-stage resource hooks
/// ([`crate::pipeline::Pipeline::stage_coverage`]) — the execution-side
/// mirror of [`latency`]: at `Fidelity::Spice` the hooks count the
/// *emitted netlists* (the §3.3 BN subtraction + scale/offset pair is two
/// crossbar stages, conv banks report their placed devices), so the model
/// reflects the circuits actually simulated rather than the closed-form
/// mapper counts.
pub fn latency_coverage(
    stages: &[StageCoverage],
    dev: &DeviceJson,
    mode: MapMode,
) -> LatencyBreakdown {
    let n_m: usize = stages.iter().map(|s| s.memristor_stages).sum();
    let t_o = dev.t_opamp * mode.opamps_per_port() as f64;
    let t_rest: f64 = stages.iter().map(|s| t_rest_of(s.kind)).sum();
    let total = (dev.t_mem + t_o) * n_m as f64 + t_rest;
    LatencyBreakdown { n_m, t_mem: dev.t_mem, t_opamp: t_o, t_rest, total }
}

/// Eq 18 over stage coverage — the companion of [`latency_coverage`].
/// Aux (CMOS) hardware is counted by each stage's `cmos_elements` record:
/// per processed element for activation circuits (what the spice
/// execution model drives), the squeezed activations + per-channel trunk
/// multipliers for SE stages, one summing amplifier per channel for
/// residual adders — whereas the mapper's [`energy`] counts per-channel
/// banks throughout.
pub fn energy_coverage(
    stages: &[StageCoverage],
    dev: &DeviceJson,
    t: &LatencyBreakdown,
) -> EnergyBreakdown {
    let memristors: usize = stages.iter().map(|s| s.memristors).sum();
    let opamps: usize = stages.iter().map(|s| s.opamps).sum();
    let e_mem = memristors as f64 * dev.p_memristor * t.t_mem * t.n_m as f64;
    let e_op = opamps as f64 * dev.p_opamp * dev.t_opamp;
    let aux: usize = stages.iter().map(|s| s.cmos_elements).sum();
    let e_rest = aux as f64 * dev.p_aux * t.t_rest.max(T_ACT);
    EnergyBreakdown {
        e_memristors: e_mem,
        e_opamps: e_op,
        e_rest,
        total: e_mem + e_op + e_rest,
    }
}

#[derive(Debug, Clone)]
pub struct EnergyBreakdown {
    /// memristor crossbar dissipation over the analog settle window
    pub e_memristors: f64,
    /// op-amp dissipation over their transition windows
    pub e_opamps: f64,
    /// activation / adder / multiplier modules
    pub e_rest: f64,
    pub total: f64,
}

/// Eq 18 over a mapped network. `t` is the matching latency breakdown.
pub fn energy(net: &MappedNetwork, dev: &DeviceJson, t: &LatencyBreakdown) -> EnergyBreakdown {
    // Σ U_max² G_max * T_m: every placed memristor at worst-case bias for
    // the crossbar response window of its stage (paper's §5.3 estimate:
    // p_memristor = U_max² G_max ≈ 1.1 µW per device).
    let e_mem = net.total_memristors() as f64 * dev.p_memristor * t.t_mem * t.n_m as f64;
    // op-amps burn P_o during their transition each stage they participate in
    let e_op = net.total_opamps() as f64 * dev.p_opamp * dev.t_opamp;
    let aux_count: usize = net
        .layers
        .iter()
        .filter(|l| matches!(l.kind, "HSwish" | "HSigmoid" | "ReLU" | "Add"))
        .map(|l| l.banks)
        .sum();
    let e_rest = aux_count as f64 * dev.p_aux * t.t_rest.max(T_ACT);
    EnergyBreakdown {
        e_memristors: e_mem,
        e_opamps: e_op,
        e_rest,
        total: e_mem + e_op + e_rest,
    }
}

/// Measured (time-domain simulated) per-read figures, as produced by
/// [`crate::netlist::CrossbarSim::tran_read`] — the counterpart of the
/// per-stage analytical terms in Eq 17/18.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimulatedRead {
    /// Output settling latency of one read pulse (s).
    pub settle_s: f64,
    /// Device energy integrated over the read trajectory (J).
    pub energy_j: f64,
}

/// One crossbar read, simulated vs analytical.
///
/// Latency: Eq 17's single-stage term `T_m + T_o` against the transient
/// settling time. Energy: Eq 18's device term (worst-case bias over the
/// `T_m` window only) against the integrated device dissipation — the
/// transient keeps devices biased for the *whole* settle, so
/// `analytical_energy_biased_j` (same worst-case power over the full
/// `T_m + T_o` window) is the like-for-like analytical column and
/// [`ReadComparison::energy_ratio`] is measured against it.
#[derive(Debug, Clone)]
pub struct ReadComparison {
    pub analytical_latency_s: f64,
    pub simulated_latency_s: f64,
    /// Eq 18 device term: `n_mem · p_memristor · T_m`.
    pub analytical_energy_j: f64,
    /// Devices at worst-case bias for the full stage window
    /// `T_m + T_o`.
    pub analytical_energy_biased_j: f64,
    pub simulated_energy_j: f64,
}

impl ReadComparison {
    pub fn new(
        dev: &DeviceJson,
        mode: MapMode,
        n_memristors: usize,
        sim: &SimulatedRead,
    ) -> ReadComparison {
        let t_o = dev.t_opamp * mode.opamps_per_port() as f64;
        let p_worst = n_memristors as f64 * dev.p_memristor;
        ReadComparison {
            analytical_latency_s: dev.t_mem + t_o,
            simulated_latency_s: sim.settle_s,
            analytical_energy_j: p_worst * dev.t_mem,
            analytical_energy_biased_j: p_worst * (dev.t_mem + t_o),
            simulated_energy_j: sim.energy_j,
        }
    }

    /// Simulated / analytical settling latency (>1: the analytical
    /// model is optimistic for this circuit).
    pub fn latency_ratio(&self) -> f64 {
        self.simulated_latency_s / self.analytical_latency_s
    }

    /// Simulated / analytical (full-window) device energy. Typically <1:
    /// the worst-case `U_max² G_max` bias overestimates real reads.
    pub fn energy_ratio(&self) -> f64 {
        self.simulated_energy_j / self.analytical_energy_biased_j
    }
}

/// Speedup/savings summary vs the paper's baselines + a measured digital
/// latency on this host (Fig 8 + §5.2/§5.3 headline ratios).
#[derive(Debug, Clone)]
pub struct Comparison {
    pub t_analog: f64,
    pub t_gpu: f64,
    pub t_cpu: f64,
    pub t_digital_host: Option<f64>,
    pub e_analog: f64,
    pub e_gpu: f64,
    pub e_cpu: f64,
}

impl Comparison {
    pub fn speedup_vs_gpu(&self) -> f64 {
        self.t_gpu / self.t_analog
    }

    pub fn speedup_vs_cpu(&self) -> f64 {
        self.t_cpu / self.t_analog
    }

    pub fn savings_vs_gpu(&self) -> f64 {
        self.e_gpu / self.e_analog
    }

    pub fn savings_vs_cpu(&self) -> f64 {
        self.e_cpu / self.e_analog
    }
}

pub fn compare(
    t: &LatencyBreakdown,
    e: &EnergyBreakdown,
    t_digital_host: Option<f64>,
) -> Comparison {
    Comparison {
        t_analog: t.total,
        t_gpu: T_GPU_RTX4090,
        t_cpu: T_CPU_I7_12700,
        t_digital_host,
        e_analog: e.total,
        e_gpu: E_GPU_RTX4090,
        e_cpu: E_CPU_I7_12700,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::{MapMode, MappedLayer, MappedNetwork};

    fn dev() -> DeviceJson {
        DeviceJson {
            r_on: 100.0,
            r_off: 16000.0,
            levels: 64,
            prog_sigma: 0.01,
            v_in: 2.5e-3,
            v_rail: 8.0,
            t_mem: 100e-12,
            slew_rate: 10e6,
            v_swing: 5.0,
            p_opamp: 1e-3,
            p_memristor: 1.1e-6,
            p_aux: 5e-4,
            t_opamp: 0.5e-6,
        }
    }

    fn layer(kind: &'static str, mem: usize, ops: usize, stage: bool) -> MappedLayer {
        MappedLayer {
            unit: "u".into(),
            name: "l".into(),
            kind,
            size: None,
            banks: 1,
            memristors: mem,
            opamps: ops,
            formula_memristors: mem,
            formula_opamps: ops,
            parallelism: 1,
            is_memristor_stage: stage,
        }
    }

    fn net(mode: MapMode) -> MappedNetwork {
        MappedNetwork {
            mode,
            layers: vec![
                layer("Conv", 1000, 16, true),
                layer("BN", 64, 32, true),
                layer("HSwish", 0, 64, false),
                layer("FC", 5000, 10, true),
            ],
        }
    }

    #[test]
    fn eq17_structure() {
        let n = net(MapMode::Inverted);
        let t = latency(&n, &dev());
        assert_eq!(t.n_m, 3);
        let expect = (100e-12 + 0.5e-6) * 3.0 + (T_ACT + T_MUL);
        assert!((t.total - expect).abs() < 1e-15);
    }

    #[test]
    fn dual_mode_is_slower() {
        let ti = latency(&net(MapMode::Inverted), &dev());
        let td = latency(&net(MapMode::Dual), &dev());
        assert!(td.total > ti.total, "dual {} vs inverted {}", td.total, ti.total);
        // paper: 1.30 µs vs 1.24 µs — same order of effect
        assert!(td.total / ti.total < 2.5);
    }

    #[test]
    fn latency_microsecond_scale() {
        let t = latency(&net(MapMode::Inverted), &dev());
        assert!(t.total > 0.1e-6 && t.total < 100e-6, "{}", t.total);
    }

    #[test]
    fn eq18_components_positive() {
        let n = net(MapMode::Inverted);
        let t = latency(&n, &dev());
        let e = energy(&n, &dev(), &t);
        assert!(e.e_memristors > 0.0 && e.e_opamps > 0.0 && e.e_rest > 0.0);
        assert!((e.total - (e.e_memristors + e.e_opamps + e.e_rest)).abs() < 1e-18);
    }

    fn cov(kind: &'static str, mem: usize, ops: usize, stages: usize, dim: usize) -> StageCoverage {
        StageCoverage {
            unit: "u".into(),
            name: "s".into(),
            kind,
            in_dim: dim,
            out_dim: dim,
            memristors: mem,
            opamps: ops,
            memristor_stages: stages,
            spice_circuits: stages,
            // aux CMOS hardware exists exactly for the T_r-contributing kinds
            cmos_elements: if t_rest_of(kind) > 0.0 { dim } else { 0 },
        }
    }

    #[test]
    fn coverage_latency_counts_stage_hooks() {
        // a spice-mode BN reports its two-stage netlist pair: N_m reflects it
        let stages = vec![
            cov("Conv", 1000, 16, 1, 64),
            cov("BN", 256, 128, 2, 64),
            cov("HSwish", 0, 64, 0, 64),
            cov("GAPool", 64, 4, 1, 4),
            cov("FC", 5000, 10, 1, 10),
        ];
        let t = latency_coverage(&stages, &dev(), MapMode::Inverted);
        assert_eq!(t.n_m, 5);
        let expect = (100e-12 + 0.5e-6) * 5.0 + (T_ACT + T_MUL);
        assert!((t.total - expect).abs() < 1e-15);
        // dual mode doubles the op-amp transition, as in the mapper model
        let td = latency_coverage(&stages, &dev(), MapMode::Dual);
        assert!(td.total > t.total);
    }

    #[test]
    fn coverage_energy_components_positive_and_sum() {
        let stages = vec![
            cov("BN", 256, 128, 2, 64),
            cov("HSigmoid", 0, 4, 0, 16),
            cov("Add", 0, 16, 0, 16),
        ];
        let t = latency_coverage(&stages, &dev(), MapMode::Inverted);
        let e = energy_coverage(&stages, &dev(), &t);
        assert!(e.e_memristors > 0.0 && e.e_opamps > 0.0 && e.e_rest > 0.0);
        assert!((e.total - (e.e_memristors + e.e_opamps + e.e_rest)).abs() < 1e-18);
    }

    #[test]
    fn read_comparison_columns() {
        let d = dev();
        let sim = SimulatedRead { settle_s: 2.3e-6, energy_j: 1e-9 };
        let c = ReadComparison::new(&d, MapMode::Inverted, 1000, &sim);
        assert!((c.analytical_latency_s - (100e-12 + 0.5e-6)).abs() < 1e-18);
        assert!(c.analytical_energy_biased_j > c.analytical_energy_j);
        let want_ratio = 2.3e-6 / (100e-12 + 0.5e-6);
        assert!((c.latency_ratio() - want_ratio).abs() < 1e-9);
        assert!(c.energy_ratio() > 0.0);
        // dual mode doubles the op-amp window in both columns
        let cd = ReadComparison::new(&d, MapMode::Dual, 1000, &sim);
        assert!(cd.analytical_latency_s > c.analytical_latency_s);
        assert!(cd.analytical_energy_biased_j > c.analytical_energy_biased_j);
    }

    #[test]
    fn headline_ratios_hold() {
        // analog latency must beat the GPU/CPU baselines by orders of
        // magnitude (paper: 138x / 2827x)
        let n = net(MapMode::Inverted);
        let t = latency(&n, &dev());
        let e = energy(&n, &dev(), &t);
        let c = compare(&t, &e, None);
        assert!(c.speedup_vs_gpu() > 50.0);
        assert!(c.speedup_vs_cpu() > 1000.0);
        assert!(c.savings_vs_gpu() > 1.0);
        assert!(c.savings_vs_cpu() > 10.0);
    }
}
