"""Trainer smoke tests — a few SGD steps must run and reduce the loss."""

import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile import train as T

WIDTH = 0.25


def test_split_params_partitions():
    p = M.init_params(0, WIDTH)
    trained, stats = T.split_params(p)
    assert set(trained) | set(stats) == set(p)
    assert not (set(trained) & set(stats))
    assert all(k.endswith(".mean") or k.endswith(".var") for k in stats)


def test_cross_entropy_smoothing():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]])
    y = jnp.asarray([0, 1])
    ce = float(T.cross_entropy(logits, y, smooth=0.0))
    assert ce < 1e-3
    ce_s = float(T.cross_entropy(logits, y, smooth=0.1))
    assert ce_s > ce  # smoothing keeps a loss floor


def test_augment_preserves_shape_and_range():
    rng = np.random.default_rng(0)
    x, _ = D.make_dataset(8, seed=1)
    out = T.augment(rng, x)
    assert out.shape == x.shape
    assert out.min() >= 0.0 and out.max() <= 1.0


def test_few_steps_reduce_loss():
    xs, ys = D.make_dataset(96, seed=3)
    params = M.init_params(0, WIDTH)
    trained, stats = T.split_params(params)
    trained = {k: jnp.asarray(v) for k, v in trained.items()}
    stats = {k: jnp.asarray(v) for k, v in stats.items()}
    vel = {k: jnp.zeros_like(v) for k, v in trained.items()}
    step = T.make_step(WIDTH, lambda it: 0.2)
    rng = np.random.default_rng(0)
    losses = []
    for it in range(6):
        idx = rng.integers(0, 96, 32)
        trained, stats, vel, loss, _ = step(
            trained, stats, vel, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]), it)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"loss did not drop: {losses}"


def test_evaluate_runs():
    params = M.init_params(0, WIDTH)
    xs, ys = D.make_dataset(20, seed=4)
    acc = T.evaluate(params, xs, ys, WIDTH, batch=10)
    assert 0.0 <= acc <= 1.0
