//! Property-based tests (util::prop mini-harness; proptest is not in the
//! offline crate cache) over the coordinator invariants, the layout
//! formulas, the solver and the JSON codec.

use memx::coordinator::batcher::plan_batch;
use memx::mapper::layout::{
    out_dim, p_neg, p_pos, place_conv_kernel, place_fc, ConvXbarGeom, FcXbarGeom,
};
use memx::mapper::{self, MapMode};
use memx::netlist::plan_segments;
use memx::spice::solve::SparseSys;
use memx::util::json::Json;
use memx::util::prng::Rng;
use memx::util::prop::check;

#[test]
fn prop_eq1_consistent_with_placement_bounds() {
    check(
        "eq1-bounds",
        200,
        |rng: &mut Rng, size: usize| {
            let w = 3 + rng.below(4 + size * 2);
            let k = 1 + rng.below(w.min(5));
            let p = rng.below(k); // padding < kernel
            let s = 1 + rng.below(2);
            (w, k, p, s)
        },
        |&(w, k, p, s)| {
            let o = out_dim(w, k, p, s);
            // last window must fit in the padded input
            (o - 1) * s + k <= w + 2 * p && o >= 1
        },
    );
}

#[test]
fn prop_eq23_rows_disjoint_regions() {
    check(
        "eq2-eq3-regions",
        100,
        |rng: &mut Rng, size: usize| {
            let w = 3 + rng.below(3 + size);
            let k = 1 + rng.below(w.min(4));
            let s = 1 + rng.below(2);
            (w, k, s, rng.next_u64())
        },
        |&(w, k, s, _)| {
            let g = ConvXbarGeom::from_conv(w, w, k, s, 0);
            let region = g.wr * g.wc;
            (0..g.cols()).all(|i| {
                let pp = p_pos(i, g.oc, g.wc, s);
                let pn = p_neg(i, g.oc, g.wr, g.wc, s);
                pp < region && pn >= region && pn == pp + region
            })
        },
    );
}

#[test]
fn prop_placement_device_count_equals_nonzeros_times_outputs() {
    check(
        "placement-count",
        100,
        |rng: &mut Rng, size: usize| {
            let w = 4 + rng.below(3 + size);
            let k = 1 + rng.below(3);
            let kernel: Vec<f64> = (0..k * k)
                .map(|_| {
                    if rng.f64() < 0.3 {
                        0.0
                    } else {
                        rng.range_f64(-1.0, 1.0)
                    }
                })
                .collect();
            (w, k, kernel)
        },
        |(w, k, kernel)| {
            let g = ConvXbarGeom::from_conv(*w, *w, *k, 1, 0);
            let placed = place_conv_kernel(&g, kernel, true);
            let nnz = kernel.iter().filter(|&&v| v != 0.0).count();
            placed.len() == nnz * g.cols()
        },
    );
}

#[test]
fn prop_fc_eval_is_linear() {
    // crossbar transfer must be linear below the rails: f(a+b) = f(a)+f(b)
    check(
        "fc-linearity",
        60,
        |rng: &mut Rng, size: usize| {
            let cin = 2 + rng.below(4 + size);
            let cout = 1 + rng.below(3 + size / 2);
            (cin, cout, rng.next_u64())
        },
        |&(cin, cout, seed)| {
            let cb = mapper::build_synthetic_fc(cin, cout, 64, MapMode::Inverted, seed);
            let mut rng = Rng::new(seed ^ 0xabc);
            let a: Vec<f64> = (0..cin).map(|_| rng.range_f64(-0.3, 0.3)).collect();
            let b: Vec<f64> = (0..cin).map(|_| rng.range_f64(-0.3, 0.3)).collect();
            let ab: Vec<f64> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
            let fa = cb.eval_ideal(&a);
            let fb = cb.eval_ideal(&b);
            let fab = cb.eval_ideal(&ab);
            fab.iter()
                .zip(fa.iter().zip(&fb))
                .all(|(s, (x, y))| (s - (x + y)).abs() < 1e-9)
        },
    );
}

#[test]
fn prop_quantize_error_bounded() {
    check(
        "quantize-bound",
        200,
        |rng: &mut Rng, _| (rng.range_f64(0.0, 1.0), 2 + rng.below(255)),
        |&(x, levels)| {
            let q = mapper::quantize_unit(x, levels);
            (q - x).abs() <= 0.5 / (levels - 1) as f64 + 1e-12 && (0.0..=1.0).contains(&q)
        },
    );
}

#[test]
fn prop_fc_dual_inverted_same_function() {
    check(
        "dual-inverted-equal",
        40,
        |rng: &mut Rng, size: usize| (2 + rng.below(4 + size), 1 + rng.below(4), rng.next_u64()),
        |&(cin, cout, seed)| {
            let a = mapper::build_synthetic_fc(cin, cout, 64, MapMode::Inverted, seed);
            let b = mapper::build_synthetic_fc(cin, cout, 64, MapMode::Dual, seed);
            let mut rng = Rng::new(seed);
            let v: Vec<f64> = (0..cin).map(|_| rng.range_f64(-0.5, 0.5)).collect();
            a.eval_ideal(&v)
                .iter()
                .zip(b.eval_ideal(&v))
                .all(|(x, y)| (x - y).abs() < 1e-12)
        },
    );
}

#[test]
fn prop_fc_placement_one_side() {
    check(
        "fc-one-side",
        60,
        |rng: &mut Rng, size: usize| {
            let cin = 1 + rng.below(5 + size);
            let cout = 1 + rng.below(4);
            let w: Vec<f64> = (0..cin * cout).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            (cin, cout, w)
        },
        |(cin, cout, w)| {
            let g = FcXbarGeom { cin: *cin, cout: *cout };
            let placed = place_fc(&g, w, None, true);
            // at most one device per (row mod cin, col)
            let mut seen = std::collections::HashSet::new();
            placed.iter().all(|p| p.row < g.rows() - 2 && seen.insert((p.row % cin, p.col)))
        },
    );
}

#[test]
fn prop_segments_partition_columns() {
    check(
        "segments-partition",
        100,
        |rng: &mut Rng, size: usize| (1 + rng.below(50 * size), rng.below(70)),
        |&(cols, seg)| {
            let segs = plan_segments(cols, seg);
            let mut covered = 0;
            let mut prev_end = 0;
            for s in &segs {
                if s.col_start != prev_end {
                    return false;
                }
                covered += s.col_end - s.col_start;
                prev_end = s.col_end;
            }
            covered == cols && prev_end == cols
        },
    );
}

#[test]
fn prop_batcher_never_exceeds_queue_or_sizes() {
    check(
        "batcher-sound",
        150,
        |rng: &mut Rng, _| {
            let avail = vec![1usize, 8, 32];
            (avail, rng.below(100), rng.bool())
        },
        |(avail, queued, waited)| match plan_batch(avail, *queued, *waited) {
            None => *queued == 0 || (!waited && *queued < 32),
            Some(p) => {
                avail.contains(&p.size) && p.real <= p.size && p.real <= *queued && p.real > 0
            }
        },
    );
}

#[test]
fn prop_sparse_solver_residual_small() {
    check(
        "sparse-residual",
        40,
        |rng: &mut Rng, size: usize| {
            let n = 3 + rng.below(5 + size * 4);
            let mut sys = SparseSys::new(n);
            for i in 0..n {
                for _ in 0..3 {
                    sys.add(i, rng.below(n), rng.range_f64(-1.0, 1.0));
                }
                sys.add(i, i, 4.0 + rng.f64());
                sys.add_b(i, rng.range_f64(-2.0, 2.0));
            }
            sys
        },
        |sys| match sys.solve() {
            // loose absolute bound: random ill-scaled systems accumulate
            // ~1e-6 residuals in f64; a *wrong* solve shows O(1) residuals,
            // which is what this property guards against
            Ok(x) => sys.residual(&x) < 1e-4,
            Err(_) => false,
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.below(8);
                Json::Str((0..n).map(|_| char::from(32 + rng.below(94) as u8)).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(
        "json-roundtrip",
        120,
        |rng: &mut Rng, size: usize| gen_json(rng, (size / 6).min(3)),
        |v| Json::parse(&v.to_string()).map(|p| p == *v).unwrap_or(false),
    );
}

#[test]
fn prop_prng_shuffle_preserves_multiset() {
    check(
        "shuffle-multiset",
        60,
        |rng: &mut Rng, size: usize| {
            let n = 1 + rng.below(10 * size);
            let v: Vec<u32> = (0..n).map(|_| rng.below(16) as u32).collect();
            (v, rng.next_u64())
        },
        |(v, seed)| {
            let mut shuffled = v.clone();
            Rng::new(*seed).shuffle(&mut shuffled);
            let mut a = v.clone();
            let mut b = shuffled;
            a.sort();
            b.sort();
            a == b
        },
    );
}
