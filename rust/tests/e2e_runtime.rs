//! End-to-end runtime tests: PJRT engine + coordinator over the real AOT
//! artifacts. These are the heaviest tests (XLA compiles + analog-model
//! executions); they skip gracefully without artifacts, and the whole file
//! is compiled out unless the `runtime-xla` feature is enabled.

#![cfg(feature = "runtime-xla")]

use std::path::{Path, PathBuf};

use memx::coordinator::{accuracy, classify_dataset, Backend, Server, ServerConfig};
use memx::runtime::{argmax_rows, Engine, Model};
use memx::util::bin::{read_expected_logits, Dataset};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn digital_model_matches_python_accuracy() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let ds = Dataset::load(&dir.join(&engine.manifest().dataset_file)).unwrap();
    let (labels, _) = classify_dataset(&engine, Model::Digital, &ds, 64).unwrap();
    let acc = accuracy(&labels, &ds.labels[..labels.len()]);
    assert!(acc > 0.9, "digital accuracy {acc}");
}

#[test]
fn analog_model_reproduces_table1() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let ds = Dataset::load(&dir.join(&engine.manifest().dataset_file)).unwrap();
    let (labels, _) = classify_dataset(&engine, Model::Analog, &ds, 32).unwrap();
    let acc = accuracy(&labels, &ds.labels[..labels.len()]);
    assert!(acc > 0.9, "memristor paradigm accuracy {acc} (paper: >90%)");
}

#[test]
fn analog_logits_match_python_export() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let m = engine.manifest();
    let ds = Dataset::load(&dir.join(&m.dataset_file)).unwrap();
    let (n, classes, expected) = read_expected_logits(&dir.join(&m.expected_file)).unwrap();
    let take = n.min(32);
    let exec = engine.get(Model::Analog, engine.pick_batch(take)).unwrap();
    let img = ds.image_len();
    let mut buf = vec![0f32; exec.batch * img];
    for j in 0..exec.batch {
        buf[j * img..(j + 1) * img].copy_from_slice(ds.image(j.min(take - 1)));
    }
    let got = exec.run(&buf).unwrap();
    let mut worst = 0f64;
    for j in 0..take.min(exec.batch) {
        for c in 0..classes {
            worst = worst
                .max((got[j * classes + c] as f64 - expected[j * classes + c] as f64).abs());
        }
    }
    assert!(worst < 1e-3, "rust PJRT vs python jit diverged: {worst:.3e}");
}

#[test]
fn batch_variants_agree() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let ds = Dataset::load(&dir.join(&engine.manifest().dataset_file)).unwrap();
    let img = ds.image_len();
    let b1 = engine.get(Model::Digital, 1).unwrap();
    let b8 = engine.get(Model::Digital, 8).unwrap();
    let mut buf8 = vec![0f32; 8 * img];
    for j in 0..8 {
        buf8[j * img..(j + 1) * img].copy_from_slice(ds.image(j));
    }
    let out8 = b8.run(&buf8).unwrap();
    for j in 0..8 {
        let out1 = b1.run(ds.image(j)).unwrap();
        for c in 0..b1.num_classes {
            let d = (out1[c] - out8[j * b1.num_classes + c]).abs();
            assert!(d < 1e-4, "img {j} class {c}: b1 {} vs b8 {}", out1[c], out8[j * 10 + c]);
        }
    }
}

#[test]
fn pallas_kernel_lowering_matches_served_artifact() {
    // the serving artifact uses the fast dot-form lowering; the pallas
    // interpret-mode lowering of the SAME analog model must agree (L1<->L2
    // cross-check at the compiled-artifact level)
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    if !engine.manifest().artifacts.contains_key("model_kernelpath_b8") {
        eprintln!("skipping: kernel-path artifact not exported");
        return;
    }
    let ds = Dataset::load(&dir.join(&engine.manifest().dataset_file)).unwrap();
    let fast = engine.get(Model::Analog, 8).unwrap();
    let kern = engine.compile_key("model_kernelpath_b8", 8).unwrap();
    let img = ds.image_len();
    let mut buf = vec![0f32; 8 * img];
    for j in 0..8 {
        buf[j * img..(j + 1) * img].copy_from_slice(ds.image(j));
    }
    let a = fast.run(&buf).unwrap();
    let b = kern.run(&buf).unwrap();
    let worst = a
        .iter()
        .zip(&b)
        .fold(0f64, |m, (x, y)| m.max((x - y).abs() as f64));
    assert!(worst < 1e-3, "kernel vs dot lowering diverged: {worst:.3e}");
}

#[test]
fn engine_rejects_bad_input_size() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let exec = engine.get(Model::Digital, 1).unwrap();
    assert!(exec.run(&[0.0; 7]).is_err());
}

#[test]
fn pick_batch_policy() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    assert_eq!(engine.pick_batch(1), 1);
    assert_eq!(engine.pick_batch(7), 1);
    assert_eq!(engine.pick_batch(8), 8);
    assert_eq!(engine.pick_batch(31), 8);
    assert_eq!(engine.pick_batch(100), 32);
}

#[test]
fn server_serves_concurrent_clients() {
    let dir = require_artifacts!();
    let ds = {
        let m = memx::nn::Manifest::load(&dir).unwrap();
        Dataset::load(&dir.join(&m.dataset_file)).unwrap()
    };
    let server = Server::start(
        &dir,
        ServerConfig {
            backend: Backend::Pjrt { model: Model::Digital },
            max_wait: std::time::Duration::from_millis(1),
        },
    )
    .unwrap();
    let n = 24;
    let correct = std::sync::atomic::AtomicUsize::new(0);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let client = server.client();
    std::thread::scope(|s| {
        for _ in 0..3 {
            let c = client.clone();
            let ds = &ds;
            let correct = &correct;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let p = c.classify(ds.image(i).to_vec()).unwrap();
                assert_eq!(p.logits.len(), 10);
                if p.label == ds.labels[i] as usize {
                    correct.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            });
        }
    });
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, n as u64);
    assert_eq!(snap.errors, 0);
    assert!(snap.batches >= 1);
    let acc = correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / n as f64;
    assert!(acc > 0.9, "served accuracy {acc}");
    server.shutdown();
}

#[test]
fn server_rejects_malformed_image() {
    let dir = require_artifacts!();
    let server = Server::start(&dir, ServerConfig::default()).unwrap();
    let client = server.client();
    assert!(client.classify(vec![0.0; 5]).is_err());
    server.shutdown();
}

#[test]
fn argmax_consistency_with_served_labels() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let ds = Dataset::load(&dir.join(&engine.manifest().dataset_file)).unwrap();
    let exec = engine.get(Model::Digital, 1).unwrap();
    let logits = exec.run(ds.image(0)).unwrap();
    let l = argmax_rows(&logits, exec.num_classes)[0];
    assert_eq!(l, ds.labels[0] as usize);
}
