//! End-to-end telemetry contracts: a Spice-fidelity `demo_network` forward
//! emits a well-formed, strictly-nested chrome trace; disabled-level
//! tracing adds zero events; and the GMRES iteration counter is exact when
//! the Krylov sweeps run on `pool` worker threads.

use std::sync::Mutex;

use memx::mapper::{build_synthetic_fc, MapMode};
use memx::netlist::CrossbarSim;
use memx::pipeline::{default_device, demo_network, Fidelity, PipelineBuilder, SolverStrategy};
use memx::spice::solve::Ordering;
use memx::telemetry::{self, Level, Ph, TraceEvent};
use memx::util::json::Json;
use memx::util::prng::Rng;

/// The tracing level and collector are process-global; serialize the tests
/// in this binary so one test's drain never swallows another's spans.
static GATE: Mutex<()> = Mutex::new(());

fn lock_telemetry() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|p| p.into_inner())
}

fn demo_inputs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..dim).map(|_| rng.f32() as f64 * 0.5).collect()).collect()
}

/// Spans on one trace tid must form a laminar family: any two either nest
/// or are disjoint (shared endpoints allowed — a child may close in the
/// same nanosecond tick its parent does). Virtual tracks (request
/// lifetimes) are exempt by construction; none exist in these tests.
fn assert_strictly_nested(events: &[TraceEvent]) {
    use std::collections::BTreeMap;
    let mut by_tid: BTreeMap<u64, Vec<(u64, u64)>> = BTreeMap::new();
    for e in events {
        if e.ph == Ph::Span {
            by_tid.entry(e.tid).or_default().push((e.ts_ns, e.ts_ns + e.dur_ns));
        }
    }
    assert!(!by_tid.is_empty(), "no spans recorded");
    for (tid, mut spans) in by_tid {
        // parents first: by start ascending, then longest first
        spans.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        let mut stack: Vec<(u64, u64)> = Vec::new();
        for (s, t) in spans {
            while let Some(&(_, pe)) = stack.last() {
                if s >= pe {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&(ps, pe)) = stack.last() {
                assert!(
                    s >= ps && t <= pe,
                    "tid {tid}: span [{s}, {t}] partially overlaps enclosing [{ps}, {pe}]"
                );
            }
            stack.push((s, t));
        }
    }
}

/// The golden-file contract: a Spice-fidelity forward through the demo
/// network produces chrome-trace JSON that parses, uses only valid phases,
/// carries non-negative microsecond timestamps, and whose spans nest
/// strictly per thread across the whole hierarchy (execution unit ->
/// module -> segment solve -> factor/substitution kernel).
#[test]
fn spice_forward_emits_wellformed_nested_chrome_trace() {
    let _g = lock_telemetry();
    telemetry::set_level(Level::Spans);
    telemetry::clear();

    let (m, ws) = demo_network(0x7E1E).unwrap();
    // workers(1) keeps every solve inline on this thread, so hierarchy
    // containment is checkable on a single track
    let mut p = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(8)
        .workers(1)
        .build(&m, &ws)
        .unwrap();
    let batch = demo_inputs(2, p.in_dim(), 0x7E1E2);
    p.forward_batch(&batch).unwrap();

    telemetry::set_level(Level::Off);
    let events = telemetry::drain();
    assert!(!events.is_empty(), "an instrumented forward must record spans");
    for cat in ["pipeline", "module", "solve", "kernel"] {
        assert!(events.iter().any(|e| e.cat == cat), "missing span category {cat}");
    }
    assert_strictly_nested(&events);

    // hierarchy: some kernel span sits inside a solve span, which sits
    // inside a module span, which sits inside a unit (pipeline) span
    let contains = |outer: &TraceEvent, inner: &TraceEvent| {
        outer.tid == inner.tid
            && inner.ts_ns >= outer.ts_ns
            && inner.ts_ns + inner.dur_ns <= outer.ts_ns + outer.dur_ns
    };
    let chain_found = events.iter().filter(|e| e.cat == "kernel").any(|k| {
        events.iter().filter(|s| s.cat == "solve" && contains(s, k)).any(|s| {
            events.iter().filter(|mo| mo.cat == "module" && contains(mo, s)).any(|mo| {
                events.iter().any(|u| u.cat == "pipeline" && contains(u, mo))
            })
        })
    });
    assert!(chain_found, "no kernel span nested under solve under module under unit");

    // chrome-trace JSON well-formedness
    let doc = telemetry::chrome_trace_json(&events);
    let parsed = Json::parse(&doc).expect("chrome trace must be valid JSON");
    let arr = parsed.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
    assert!(arr.len() >= events.len(), "metadata rows + one row per event");
    for ev in arr {
        let ph = ev.get("ph").and_then(|v| v.as_str()).expect("ph");
        assert!(matches!(ph, "X" | "i" | "M"), "unexpected ph {ph:?}");
        if ph == "M" {
            continue;
        }
        assert!(ev.get("ts").and_then(|v| v.as_f64()).expect("ts") >= 0.0);
        match ph {
            "X" => {
                assert!(ev.get("dur").and_then(|v| v.as_f64()).expect("dur") >= 0.0);
            }
            _ => {
                // instants carry a thread scope instead of a duration
                assert_eq!(ev.get("s").and_then(|v| v.as_str()), Some("t"));
            }
        }
    }
}

/// The zero-cost contract's observable half: at [`Level::Off`] the same
/// instrumented forward records nothing at all.
#[test]
fn disabled_tracing_adds_zero_events() {
    let _g = lock_telemetry();
    telemetry::set_level(Level::Off);
    telemetry::clear();

    let (m, ws) = demo_network(0x0FF1).unwrap();
    let mut p = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(8)
        .workers(1)
        .build(&m, &ws)
        .unwrap();
    let batch = demo_inputs(1, p.in_dim(), 0x0FF2);
    p.forward_batch(&batch).unwrap();

    let events = telemetry::drain();
    assert!(events.is_empty(), "disabled level recorded {} event(s)", events.len());
    assert_eq!(telemetry::dropped_events(), 0);
}

/// Regression for the `precond_reused`-style plumbing: the process-wide
/// GMRES iteration counter is bumped inside the kernel itself, so it must
/// advance when the per-RHS Krylov sweeps run on `pool::par_map` worker
/// threads (`workers >= 2`), not just on the caller.
#[test]
fn gmres_iteration_counter_advances_across_worker_threads() {
    let _g = lock_telemetry();
    let dev = default_device();
    let cb = build_synthetic_fc(24, 12, dev.levels, MapMode::Inverted, 0x6E50);
    let solver = SolverStrategy::Iterative { restart: 16, tol: 1e-11, max_iter: 600 };
    // monolithic (segment 0): solve_batch hands the whole worker budget to
    // the per-RHS GMRES sweeps, the exact cross-thread path under test
    let mut sim = CrossbarSim::new(&cb, &dev, 0, Ordering::Smart, solver).unwrap();
    let mut rng = Rng::new(0x6E51);
    let inputs: Vec<Vec<f64>> =
        (0..4).map(|_| (0..24).map(|_| (rng.f64() * 2.0 - 1.0) * 0.4).collect()).collect();

    let before = memx::spice::gmres_iterations();
    let out = sim.solve_batch(&inputs, 2).unwrap();
    assert_eq!(out.len(), inputs.len());
    assert!(out.iter().flatten().all(|v| v.is_finite()));
    let after = memx::spice::gmres_iterations();
    assert!(
        after > before,
        "GMRES iterations spent on worker threads must be counted (before {before}, after {after})"
    );
    // a second identical batch rides the cached preconditioner
    let reuse_before = memx::spice::precond_reuses();
    sim.solve_batch(&inputs, 2).unwrap();
    assert!(
        memx::spice::precond_reuses() > reuse_before,
        "warm preconditioner reuse must be counted"
    );
}
