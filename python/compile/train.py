"""Offline trainer for the digital MobileNetV3 (paper §5.1: "network weights
are obtained from an offline server").

Hand-rolled SGD with Nesterov-style momentum, cosine LR, label smoothing and
light augmentation (flips + shifts); BN running statistics tracked with
momentum 0.9.  No optax in this offline image — the update rule is ~20 lines.

Usage:  cd python && python -m compile.train --out ../artifacts/params.npz
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M


def cross_entropy(logits, labels, smooth=0.1):
    n_cls = logits.shape[-1]
    onehot = jax.nn.one_hot(labels, n_cls)
    target = onehot * (1.0 - smooth) + smooth / n_cls
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(target * logp, axis=-1))


def split_params(params):
    """BN stats are not trained by gradient; gamma/beta/weights are."""
    trained = {k: v for k, v in params.items()
               if not (k.endswith(".mean") or k.endswith(".var"))}
    stats = {k: v for k, v in params.items()
             if k.endswith(".mean") or k.endswith(".var")}
    return trained, stats


def make_step(width, lr_schedule, momentum=0.9, weight_decay=1e-4):
    def loss_fn(trained, stats, x, y):
        params = {**trained, **stats}
        bn_out: dict = {}
        logits = M.forward(params, x, M.Ctx(), width=width,
                           train=True, stats_out=bn_out)
        loss = cross_entropy(logits, y)
        acc = jnp.mean(jnp.argmax(logits, -1) == y)
        return loss, (acc, bn_out)

    @jax.jit
    def step(trained, stats, vel, x, y, it):
        (loss, (acc, bn_out)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(trained, stats, x, y)
        lr = lr_schedule(it)
        new_trained, new_vel = {}, {}
        for k, g in grads.items():
            if k.endswith(".w") or k.endswith(".b"):
                g = g + weight_decay * trained[k]
            v = momentum * vel[k] + g
            new_vel[k] = v
            new_trained[k] = trained[k] - lr * v
        # running BN stats, momentum 0.9
        new_stats = dict(stats)
        for name, (m, va) in bn_out.items():
            new_stats[f"{name}.mean"] = 0.9 * stats[f"{name}.mean"] + 0.1 * m
            new_stats[f"{name}.var"] = 0.9 * stats[f"{name}.var"] + 0.1 * va
        return new_trained, new_stats, new_vel, loss, acc

    return step


def evaluate(params, xs, ys, width, batch=200):
    @jax.jit
    def fwd(x):
        return M.forward(params, x, M.Ctx(), width=width)
    correct = 0
    for i in range(0, len(xs), batch):
        logits = fwd(jnp.asarray(xs[i:i + batch]))
        correct += int(np.sum(np.argmax(np.asarray(logits), -1) == ys[i:i + batch]))
    return correct / len(xs)


def augment(rng, x):
    """Random horizontal flip + integer shift up to ±3 px (reflect pad)."""
    b = x.shape[0]
    flip = rng.uniform(size=b) < 0.5
    x = np.where(flip[:, None, None, None], x[:, :, ::-1, :], x)
    out = np.empty_like(x)
    shifts = rng.integers(-3, 4, size=(b, 2))
    for i in range(b):
        out[i] = np.roll(x[i], tuple(shifts[i]), axis=(0, 1))
    return out


def train(out_path: str, steps: int = 600, batch: int = 64, width: float = 0.4,
          n_train: int = 9000, n_test: int = 2000, seed: int = 0,
          base_lr: float = 0.4, log_every: int = 50):
    t0 = time.time()
    print(f"[train] generating synth-cifar: {n_train} train / {n_test} test")
    xs, ys = D.make_dataset(n_train, seed=1234)
    xt, yt = D.make_dataset(n_test, seed=5678)

    params = M.init_params(seed, width)
    trained, stats = split_params(params)
    vel = {k: jnp.zeros_like(v) for k, v in trained.items()}
    trained = {k: jnp.asarray(v) for k, v in trained.items()}
    stats = {k: jnp.asarray(v) for k, v in stats.items()}

    warmup = max(1, steps // 20)

    def lr_schedule(it):
        it = jnp.asarray(it, jnp.float32)
        warm = base_lr * it / warmup
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * (it - warmup) / max(1, steps - warmup)))
        return jnp.where(it < warmup, warm, cos)

    step = make_step(width, lr_schedule)
    rng = np.random.default_rng(seed + 1)
    print(f"[train] {M.count_params(params)} params, {steps} steps, batch {batch}")
    for it in range(steps):
        idx = rng.integers(0, n_train, batch)
        xb = augment(rng, xs[idx])
        trained, stats, vel, loss, acc = step(
            trained, stats, vel, jnp.asarray(xb), jnp.asarray(ys[idx]), it)
        if it % log_every == 0 or it == steps - 1:
            print(f"[train] step {it:4d}  loss {float(loss):.4f}  "
                  f"batch-acc {float(acc):.3f}  ({time.time()-t0:.0f}s)")

    params = {k: np.asarray(v) for k, v in {**trained, **stats}.items()}
    test_acc = evaluate(params, xt, yt, width)
    train_acc = evaluate(params, xs[:2000], ys[:2000], width)
    print(f"[train] digital accuracy: test {test_acc:.4f} train(2k) {train_acc:.4f}")

    np.savez(out_path, __test_acc=np.float32(test_acc),
             __width=np.float32(width), **params)
    print(f"[train] saved {out_path} in {time.time()-t0:.0f}s")
    return test_acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/params.npz")
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--width", type=float, default=0.4)
    args = ap.parse_args()
    acc = train(args.out, steps=args.steps, batch=args.batch, width=args.width)
    if acc < 0.9:
        print(f"[train] WARNING: test accuracy {acc:.3f} < 0.90 target")


if __name__ == "__main__":
    main()
