//! Standard-dialect SPICE interchange: `.SUBCKT`-structured deck emission
//! and a round-tripping parser.
//!
//! The flat emitter in the parent module ([`super::emit_crossbar`]) writes
//! netlists only this crate reads. This module speaks the ecosystem
//! dialect instead, so every resident circuit can be handed to (and read
//! back from) external SPICE tooling, and the differential harness in
//! [`super::validate`] can prove emit → parse → sim equals the resident
//! solve.
//!
//! # Dialect
//!
//! One deck is a title line, optional `.SUBCKT <name> <ports...>` /
//! `.ENDS` definitions, element / `X` instantiation cards, and a final
//! `.END`:
//!
//! ```text
//! * memx interchange deck: fc1.seg0
//! .SUBCKT fc1.seg0 in0 in1 vout0
//! Vin0 in0 0 DC 0.25
//! RM0_0 in0 vcol0 2520.3
//! RF0 vcol0 vout0 50
//! EOP0 vout0 0 0 vcol0 1000000
//! .ENDS fc1.seg0
//! X1 in0 in1 vout0 fc1.seg0
//! .END
//! ```
//!
//! Supported element cards (first letter selects the type, the full first
//! token is the element name):
//!
//! | card | element | form |
//! |------|---------|------|
//! | `R`  | resistor | `Rxx n+ n- ohms` |
//! | `V`  | voltage source | `Vxx n+ n- [DC] volts` |
//! | `I`  | current source | `Ixx n+ n- [DC] amps` |
//! | `E`  | VCVS | `Exx out+ out- ctrl+ ctrl- gain` |
//! | `G`  | VCCS | `Gxx out+ out- ctrl+ ctrl- gm` |
//! | `C`  | capacitor | `Cxx n+ n- farads` |
//! | `L`  | inductor | `Lxx n+ n- henries` |
//! | `D`  | diode | `Dxx anode cathode [isat n·Vt]` |
//! | `B`  | behavioural multiplier | `Bxx out ctrl_a ctrl_b gain` |
//! | `X`  | subcircuit instance | `Xxx n1 ... nK subckt_name` |
//!
//! Values accept engineering suffixes (`f p n u m k meg g t`, case
//! insensitive, trailing unit letters ignored: `10kohm` = `1e4`).
//! Comments start with `*`; a leading `+` continues the previous card;
//! node `0`/`gnd` is global ground (also inside subcircuits). Instantiation
//! expands recursively: port nodes map to the instance's connection nodes,
//! internal nodes and element names are prefixed `<instance>.`. Unknown
//! dot-cards (`.op`, `.model`, ...) are ignored; `.END` stops parsing.
//!
//! Every syntax failure is a structured [`ParseError`] carrying the
//! 1-based line and column of the offending token — the parser never
//! panics, and expansion is budgeted (recursion depth, total elements) so
//! hostile decks are rejected rather than exhausting memory.
//!
//! # Round-trip contract
//!
//! [`emit_cards`] serializes values with Rust's shortest-round-trip `f64`
//! formatting, so `parse` of an emitted card reconstructs bit-identical
//! element values; `emit_cards(parse(emit_cards(c))) == emit_cards(c)`
//! holds byte-for-byte (pinned by the interchange proptests). Subcircuit
//! expansion renames internal nodes, which would permute MNA unknown
//! ordering and let LU rounding drift — [`emit_deck`] therefore leads the
//! subcircuit body with inert zero-current `Ipin` sources that pin the
//! node interning order, making emit → parse → sim reproduce the resident
//! solve bit-for-bit. The conformance suite ([`super::validate`])
//! nonetheless only pins ≤ 1e-12 relative, the contract external decks
//! without pins are held to.

use std::collections::BTreeMap;

use crate::spice::{Circuit, Element};

/// One emittable circuit plus its interface: the node names that become
/// the `.SUBCKT` port list. `inputs` are the driven source nodes,
/// `outputs` the read nodes — kept separate so validation knows what to
/// compare after a round trip.
#[derive(Debug, Clone)]
pub struct Deck {
    /// Subcircuit name (also names the deck in reports).
    pub name: String,
    /// The resident circuit, current element values included.
    pub circuit: Circuit,
    /// Driven interface node names (input sources).
    pub inputs: Vec<String>,
    /// Read interface node names (column outputs, activation output).
    pub outputs: Vec<String>,
}

impl Deck {
    /// The `.SUBCKT` port list: inputs then outputs, deduplicated, ground
    /// and names not present in the circuit dropped (a port the cards
    /// never touch would parse into a floating — singular — node).
    pub fn ports(&self) -> Vec<String> {
        let mut seen = std::collections::BTreeSet::new();
        self.inputs
            .iter()
            .chain(self.outputs.iter())
            .filter(|p| {
                !is_ground(p)
                    && self.circuit.node_named(p).is_some_and(|n| n != 0)
                    && seen.insert(p.as_str().to_string())
            })
            .cloned()
            .collect()
    }
}

fn is_ground(name: &str) -> bool {
    name == "0" || name.eq_ignore_ascii_case("gnd")
}

/// Prefix `name` with the card-type letter unless it already starts with
/// it (`"RM0_1"` stays, `"XMUL"` becomes `"BXMUL"` on a multiplier card).
/// The parser keeps the full card token as the element name, so a renamed
/// element stays renamed across round trips — [`super::validate`] compares
/// against the canonicalized resident names for exactly this reason.
pub fn card_name(kind: char, name: &str) -> String {
    if name.chars().next().is_some_and(|c| c.eq_ignore_ascii_case(&kind)) {
        name.to_string()
    } else {
        format!("{kind}{name}")
    }
}

/// Serialize every element of `c` as one card per line (no title, no
/// terminator) using the circuit's node names. Values use Rust's shortest
/// round-trip `f64` formatting, so a parse of the output reconstructs the
/// exact same numbers.
pub fn emit_cards(c: &Circuit) -> String {
    let names = c.node_names();
    let n = |id: usize| names[id].as_str();
    let mut s = String::with_capacity(64 * c.elements.len());
    for e in &c.elements {
        match e {
            Element::Resistor(name, a, b, v) => {
                s.push_str(&format!("{} {} {} {v}\n", card_name('R', name), n(*a), n(*b)));
            }
            Element::Vsource(name, a, b, v) => {
                s.push_str(&format!("{} {} {} DC {v}\n", card_name('V', name), n(*a), n(*b)));
            }
            Element::Isource(name, a, b, v) => {
                s.push_str(&format!("{} {} {} DC {v}\n", card_name('I', name), n(*a), n(*b)));
            }
            Element::Vcvs(name, op, om, cp, cm, g) => {
                s.push_str(&format!(
                    "{} {} {} {} {} {g}\n",
                    card_name('E', name),
                    n(*op),
                    n(*om),
                    n(*cp),
                    n(*cm)
                ));
            }
            Element::Vccs(name, op, om, cp, cm, g) => {
                s.push_str(&format!(
                    "{} {} {} {} {} {g}\n",
                    card_name('G', name),
                    n(*op),
                    n(*om),
                    n(*cp),
                    n(*cm)
                ));
            }
            Element::Diode(name, a, k, isat, nvt) => {
                s.push_str(&format!(
                    "{} {} {} {isat} {nvt}\n",
                    card_name('D', name),
                    n(*a),
                    n(*k)
                ));
            }
            Element::Mult(name, out, a, b, g) => {
                s.push_str(&format!(
                    "{} {} {} {} {g}\n",
                    card_name('B', name),
                    n(*out),
                    n(*a),
                    n(*b)
                ));
            }
            Element::Capacitor(name, a, b, v) => {
                s.push_str(&format!("{} {} {} {v}\n", card_name('C', name), n(*a), n(*b)));
            }
            Element::Inductor(name, a, b, v) => {
                s.push_str(&format!("{} {} {} {v}\n", card_name('L', name), n(*a), n(*b)));
            }
        }
    }
    s
}

/// Render a circuit as a flat (subcircuit-free) deck: title comment,
/// cards, `.END`.
pub fn emit_flat(c: &Circuit) -> String {
    format!("* {}\n{}.END\n", c.title, emit_cards(c))
}

/// Render one deck in the interchange dialect: the circuit as a single
/// `.SUBCKT` definition with the deck's interface as its port list, one
/// `X1` instantiation wiring the ports to identically named top-level
/// nodes, `.END`-terminated.
///
/// The subcircuit body opens with one zero-current `Ipin` source per node
/// in resident node-id order. They are electrically inert (a 0 A source
/// stamps nothing into the matrix and adds exactly `±0.0` to the RHS) but
/// force the parser to intern nodes in the same order the resident
/// circuit numbered them — so the re-simulated deck assembles the
/// bit-identical MNA system and emit → parse → sim reproduces the
/// resident solve exactly, not merely to solver precision. The `Ipin`
/// element-name prefix is reserved for this purpose.
pub fn emit_deck(d: &Deck) -> String {
    let ports = d.ports().join(" ");
    let names = d.circuit.node_names();
    let mut pins =
        String::from("* node-order pins (0 A): fix MNA unknown ordering for exact round-trip\n");
    for (id, name) in names.iter().enumerate().skip(1) {
        pins.push_str(&format!("Ipin{id} {name} 0 DC 0\n"));
    }
    format!(
        "* memx interchange deck: {name}\n.SUBCKT {name} {ports}\n{pins}{cards}.ENDS {name}\nX1 {ports} {name}\n.END\n",
        name = d.name,
        cards = emit_cards(&d.circuit),
    )
}

/// Structured parse failure: 1-based line and column of the offending
/// token in the source text (for continued cards the column indexes the
/// joined logical line).
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("netlist parse error at line {line}, col {col}: {msg}")]
pub struct ParseError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

fn perr<T>(line: usize, col: usize, msg: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError { line, col, msg: msg.into() })
}

/// Parse a token as a value, honouring engineering suffixes (`f p n u m k
/// meg g t`, case-insensitive) with trailing unit letters ignored
/// (`100nF`, `10kohm`). Returns `None` for malformed or non-finite input.
pub fn parse_value(tok: &str) -> Option<f64> {
    // longest numeric prefix that parses as f64 (reject inf/nan spellings)
    let mut num: Option<(f64, usize)> = None;
    for i in (1..=tok.len()).rev() {
        if !tok.is_char_boundary(i) {
            continue;
        }
        let head = &tok[..i];
        if head.chars().any(|c| c.is_ascii_alphabetic() && !matches!(c, 'e' | 'E')) {
            continue;
        }
        if let Ok(v) = head.parse::<f64>() {
            num = Some((v, i));
            break;
        }
    }
    let (v, used) = num?;
    if !v.is_finite() {
        return None;
    }
    let rest = tok[used..].to_ascii_lowercase();
    if rest.is_empty() {
        return Some(v);
    }
    if !rest.chars().all(|c| c.is_ascii_alphabetic()) {
        return None;
    }
    let mul = if rest.starts_with("meg") {
        1e6
    } else {
        match rest.as_bytes()[0] {
            b'f' => 1e-15,
            b'p' => 1e-12,
            b'n' => 1e-9,
            b'u' => 1e-6,
            b'm' => 1e-3,
            b'k' => 1e3,
            b'g' => 1e9,
            b't' => 1e12,
            // bare unit ("10ohm", "5v"): no scaling
            _ => 1.0,
        }
    };
    Some(v * mul)
}

/// One logical card: joined continuation lines, the 1-based source line of
/// its first physical line, and its tokens with 1-based columns.
#[derive(Debug, Clone)]
struct Card {
    line: usize,
    text: String,
}

impl Card {
    fn tokens(&self) -> Vec<(usize, &str)> {
        let mut out = Vec::new();
        let mut col = 1usize;
        for piece in self.text.split(' ') {
            if !piece.is_empty() {
                out.push((col, piece));
            }
            col += piece.chars().count() + 1;
        }
        out
    }
}

#[derive(Debug, Clone)]
struct SubcktDef {
    line: usize,
    ports: Vec<String>,
    cards: Vec<Card>,
}

/// Maximum subcircuit nesting depth during expansion.
const MAX_DEPTH: usize = 32;
/// Total element budget across the whole expansion — a recursion-free
/// guard against "billion-laughs" style deck blowup.
const MAX_ELEMENTS: usize = 4_000_000;

/// Route one finished logical card: open/close `.SUBCKT` scopes, collect
/// element and `X` cards into the innermost open scope (or the top level),
/// ignore unknown dot-directives, honour `.END`.
fn dispatch_card(
    card: Card,
    open: &mut Vec<(String, SubcktDef)>,
    subckts: &mut BTreeMap<String, SubcktDef>,
    top: &mut Vec<Card>,
    ended: &mut bool,
) -> Result<(), ParseError> {
    if *ended {
        return Ok(()); // everything after .END is ignored
    }
    let toks = card.tokens();
    let Some(&(col0, first)) = toks.first() else {
        return Ok(());
    };
    if let Some(directive) = first.strip_prefix('.') {
        match directive.to_ascii_lowercase().as_str() {
            "subckt" => {
                if toks.len() < 2 {
                    return perr(card.line, col0, ".SUBCKT needs a name");
                }
                let name = toks[1].1.to_string();
                let mut ports = Vec::new();
                for &(c, p) in &toks[2..] {
                    if is_ground(p) {
                        return perr(
                            card.line,
                            c,
                            format!("ground node '{p}' cannot be a .SUBCKT port"),
                        );
                    }
                    if ports.iter().any(|q: &String| q == p) {
                        return perr(
                            card.line,
                            c,
                            format!("duplicate node '{p}' in .SUBCKT port list"),
                        );
                    }
                    ports.push(p.to_string());
                }
                open.push((name, SubcktDef { line: card.line, ports, cards: Vec::new() }));
            }
            "ends" => {
                let Some((name, def)) = open.pop() else {
                    return perr(card.line, col0, ".ENDS without an open .SUBCKT");
                };
                if let Some(&(c, given)) = toks.get(1) {
                    if given != name {
                        return perr(
                            card.line,
                            c,
                            format!(".ENDS '{given}' closes .SUBCKT '{name}'"),
                        );
                    }
                }
                if subckts.insert(name.clone(), def).is_some() {
                    return perr(
                        card.line,
                        col0,
                        format!("duplicate .SUBCKT definition '{name}'"),
                    );
                }
            }
            "end" => {
                if let Some((name, def)) = open.last() {
                    return perr(
                        card.line,
                        col0,
                        format!(
                            "truncated deck: .SUBCKT '{name}' (line {}) is unterminated",
                            def.line
                        ),
                    );
                }
                *ended = true;
            }
            // harmless analysis/config directives are ignored
            _ => {}
        }
    } else if let Some((_, def)) = open.last_mut() {
        def.cards.push(card);
    } else {
        top.push(card);
    }
    Ok(())
}

/// Parse an interchange-dialect deck (see the module docs) into a flat
/// [`Circuit`], expanding every subcircuit instantiation. Never panics;
/// every failure is a [`ParseError`] with source position.
pub fn parse_deck(text: &str) -> Result<Circuit, ParseError> {
    // ---- pass 1: logical lines -> title, subckt defs, top-level cards ----
    let mut title = String::new();
    let mut subckts: BTreeMap<String, SubcktDef> = BTreeMap::new();
    // stack of open .SUBCKT scopes: (name, def)
    let mut open: Vec<(String, SubcktDef)> = Vec::new();
    let mut top: Vec<Card> = Vec::new();
    let mut logical: Option<Card> = None;
    let mut ended = false;

    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let t = raw.replace('\t', " ");
        let t = t.trim();
        if t.starts_with('*') {
            if lineno == 1 {
                title = t.trim_start_matches('*').trim().to_string();
            }
            continue;
        }
        if let Some(cont) = t.strip_prefix('+') {
            match logical.as_mut() {
                Some(card) => {
                    card.text.push(' ');
                    card.text.push_str(cont.trim());
                }
                None => return perr(lineno, 1, "continuation line '+' with no card to continue"),
            }
            continue;
        }
        // a fresh line terminates any pending logical card
        if let Some(card) = logical.take() {
            dispatch_card(card, &mut open, &mut subckts, &mut top, &mut ended)?;
        }
        if t.is_empty() {
            continue;
        }
        let leading = t.chars().next().map_or('?', |c| c.to_ascii_uppercase());
        if lineno == 1
            && !matches!(
                leading,
                'R' | 'V' | 'I' | 'E' | 'G' | 'C' | 'L' | 'D' | 'B' | 'X' | '.'
            )
        {
            // classic SPICE: an unrecognizable first line is the title
            title = t.to_string();
            continue;
        }
        logical = Some(Card { line: lineno, text: t.to_string() });
    }
    if let Some(card) = logical.take() {
        dispatch_card(card, &mut open, &mut subckts, &mut top, &mut ended)?;
    }
    if let Some((name, def)) = open.last() {
        let total = text.lines().count().max(1);
        return perr(
            total,
            1,
            format!("truncated deck: .SUBCKT '{name}' (line {}) has no .ENDS", def.line),
        );
    }

    // ---- pass 2: expand top-level cards into a flat circuit ----
    let mut c = Circuit::new(&title);
    for card in &top {
        stamp_card(&mut c, card, &subckts, "", &BTreeMap::new(), 0)?;
    }
    Ok(c)
}

/// Resolve one node token under an instantiation scope: ground is global,
/// ports map through `bind`, everything else is prefixed by the instance
/// path.
fn resolve_node(tok: &str, prefix: &str, bind: &BTreeMap<String, String>) -> String {
    if is_ground(tok) {
        "0".to_string()
    } else if let Some(mapped) = bind.get(tok) {
        mapped.clone()
    } else {
        format!("{prefix}{tok}")
    }
}

/// Parse + stamp one element or `X` card into `c`, expanding subcircuits
/// recursively. `prefix` is the instance path (`""` at top level,
/// `"X1."` inside instance `X1`, nesting concatenates); `bind` maps this
/// scope's port names to parent-scope node names.
fn stamp_card(
    c: &mut Circuit,
    card: &Card,
    subckts: &BTreeMap<String, SubcktDef>,
    prefix: &str,
    bind: &BTreeMap<String, String>,
    depth: usize,
) -> Result<(), ParseError> {
    let toks = card.tokens();
    let Some(&(col0, first)) = toks.first() else {
        return Ok(());
    };
    let kind = first.chars().next().map_or('?', |ch| ch.to_ascii_uppercase());
    if c.elements.len() >= MAX_ELEMENTS {
        return perr(card.line, col0, "deck expansion exceeds the element budget");
    }
    let name = format!("{prefix}{first}");
    let line = card.line;

    // helpers over the token list
    let need = |n: usize, what: &str| -> Result<(), ParseError> {
        if toks.len() == n {
            Ok(())
        } else {
            perr(line, col0, format!("{what} needs {n} tokens, got {}", toks.len()))
        }
    };
    let value = |i: usize, what: &str| -> Result<f64, ParseError> {
        let &(col, tok) = toks
            .get(i)
            .ok_or(ParseError { line, col: col0, msg: format!("{what}: missing value") })?;
        parse_value(tok)
            .ok_or(ParseError { line, col, msg: format!("{what}: bad value '{tok}'") })
    };
    macro_rules! node {
        ($i:expr) => {{
            let resolved = resolve_node(toks[$i].1, prefix, bind);
            c.node(&resolved)
        }};
    }

    match kind {
        'R' => {
            need(4, "resistor")?;
            let (a, b) = (node!(1), node!(2));
            let v = value(3, "resistor")?;
            c.resistor(&name, a, b, v);
        }
        'C' => {
            need(4, "capacitor")?;
            let (a, b) = (node!(1), node!(2));
            let v = value(3, "capacitor")?;
            c.capacitor(&name, a, b, v);
        }
        'L' => {
            need(4, "inductor")?;
            let (a, b) = (node!(1), node!(2));
            let v = value(3, "inductor")?;
            c.inductor(&name, a, b, v);
        }
        'V' | 'I' => {
            let what = if kind == 'V' { "voltage source" } else { "current source" };
            let vi = if toks.len() >= 5 && toks[3].1.eq_ignore_ascii_case("dc") { 4 } else { 3 };
            if toks.len() != vi + 1 {
                return perr(line, col0, format!("{what} needs 'name n+ n- [DC] value'"));
            }
            let (a, b) = (node!(1), node!(2));
            let v = value(vi, what)?;
            if kind == 'V' {
                c.vsource(&name, a, b, v);
            } else {
                c.isource(&name, a, b, v);
            }
        }
        'E' | 'G' => {
            let what = if kind == 'E' { "VCVS" } else { "VCCS" };
            need(6, what)?;
            let (op, om, cp, cm) = (node!(1), node!(2), node!(3), node!(4));
            let g = value(5, what)?;
            if kind == 'E' {
                c.vcvs(&name, op, om, cp, cm, g);
            } else {
                c.vccs(&name, op, om, cp, cm, g);
            }
        }
        'D' => {
            if toks.len() != 3 && toks.len() != 5 {
                return perr(line, col0, "diode needs 'name anode cathode [isat nvt]'");
            }
            let (a, k) = (node!(1), node!(2));
            if toks.len() == 5 {
                let isat = value(3, "diode isat")?;
                let nvt = value(4, "diode nvt")?;
                c.elements.push(Element::Diode(name, a, k, isat, nvt));
            } else {
                c.diode(&name, a, k);
            }
        }
        'B' => {
            need(5, "behavioural multiplier")?;
            let (out, a, b) = (node!(1), node!(2), node!(3));
            let g = value(4, "behavioural multiplier")?;
            c.mult(&name, out, a, b, g);
        }
        'X' => {
            if toks.len() < 2 {
                return perr(line, col0, "subcircuit instance needs 'Xname [nodes...] subckt'");
            }
            if depth >= MAX_DEPTH {
                return perr(line, col0, "subcircuit nesting exceeds the depth budget");
            }
            let (scol, sub_name) = *toks.last().unwrap_or(&(col0, ""));
            let Some(def) = subckts.get(sub_name) else {
                return perr(line, scol, format!("undefined subcircuit '{sub_name}'"));
            };
            let args = &toks[1..toks.len() - 1];
            if args.len() != def.ports.len() {
                return perr(
                    line,
                    col0,
                    format!(
                        "subcircuit '{sub_name}' has {} ports, instance connects {}",
                        def.ports.len(),
                        args.len()
                    ),
                );
            }
            let inner_prefix = format!("{name}.");
            let mut inner_bind = BTreeMap::new();
            for (port, &(_, arg)) in def.ports.iter().zip(args) {
                inner_bind.insert(port.clone(), resolve_node(arg, prefix, bind));
            }
            for inner in &def.cards {
                stamp_card(c, inner, subckts, &inner_prefix, &inner_bind, depth + 1)?;
            }
        }
        other => {
            return perr(line, col0, format!("unsupported element '{other}'"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_suffixes() {
        assert_eq!(parse_value("10k"), Some(1e4));
        assert_eq!(parse_value("1meg"), Some(1e6));
        assert_eq!(parse_value("100n"), Some(1e-7));
        assert_eq!(parse_value("2.5u"), Some(2.5e-6));
        assert_eq!(parse_value("10kohm"), Some(1e4));
        assert_eq!(parse_value("1e6"), Some(1e6));
        assert_eq!(parse_value("-0.5"), Some(-0.5));
        assert_eq!(parse_value("3p"), Some(3e-12));
        assert_eq!(parse_value("notanumber"), None);
        assert_eq!(parse_value("1..2"), None);
        assert_eq!(parse_value("nan"), None);
        assert_eq!(parse_value("inf"), None);
    }

    #[test]
    fn flat_cards_roundtrip_bytes() {
        let mut c = Circuit::new("flat");
        let a = c.node("a");
        let b = c.node("b");
        c.vsource("V1", a, 0, 2.5);
        c.resistor("R1", a, b, 1234.5678901234);
        c.resistor("R2", b, 0, 1e6);
        c.vccs("G1", b, 0, a, 0, 1e-4);
        let t1 = emit_cards(&c);
        let c2 = parse_deck(&format!("* flat\n{t1}.END\n")).unwrap();
        assert_eq!(emit_cards(&c2), t1);
        assert_eq!(c2.elements, c.elements);
    }

    #[test]
    fn subckt_divider_solves() {
        let deck = "\
* divider via subckt
.SUBCKT div top mid
V1 top 0 DC 10
R1 top mid 10k
R2 mid gnd 10k
.ENDS div
X1 t m div
.END
";
        let c = parse_deck(deck).unwrap();
        let sol = c.dc_op().unwrap();
        let mid = c.node_named("m").unwrap();
        assert!((sol[mid] - 5.0).abs() < 1e-9, "divider mid = {}", sol[mid]);
    }

    #[test]
    fn continuation_and_suffix() {
        let deck = "* cont\nR1 a 0\n+ 10k\nV1 a 0 DC 1\n.END\n";
        let c = parse_deck(deck).unwrap();
        match &c.elements[0] {
            Element::Resistor(_, _, _, v) => assert_eq!(*v, 1e4),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn nested_subckts_expand() {
        let deck = "\
* nested
.SUBCKT leaf p
R1 p 0 1k
.ENDS leaf
.SUBCKT branch q
Xa q leaf
Xb q leaf
.ENDS branch
V1 n 0 DC 1
Xtop n branch
.END
";
        let c = parse_deck(deck).unwrap();
        // V1 + two expanded leaf resistors
        assert_eq!(c.elements.len(), 3);
        let sol = c.dc_op().unwrap();
        let n = c.node_named("n").unwrap();
        assert!((sol[n] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn structured_errors_carry_position() {
        let e = parse_deck("* t\nR1 a b\n.END\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_deck("* t\nV1 a 0 DC nope\n.END\n").unwrap_err();
        assert_eq!((e.line, e.col), (2, 11));
        let e = parse_deck("* t\nX1 a nosuch\n.END\n").unwrap_err();
        assert!(e.msg.contains("undefined subcircuit"), "{e}");
        let e = parse_deck("* t\n.SUBCKT s p p\nR1 p 0 1\n.ENDS s\n.END\n").unwrap_err();
        assert!(e.msg.contains("duplicate node"), "{e}");
        let e = parse_deck("* t\n.SUBCKT s p\nR1 p 0 1\n.END\n").unwrap_err();
        assert!(e.msg.contains("truncated"), "{e}");
        let e = parse_deck("* t\n.SUBCKT s p\nR1 p 0 1\n").unwrap_err();
        assert!(e.msg.contains("truncated"), "{e}");
    }

    #[test]
    fn deck_ports_filter_ground_and_unknowns() {
        let mut c = Circuit::new("p");
        let a = c.node("a");
        c.resistor("R1", a, 0, 50.0);
        let d = Deck {
            name: "p".into(),
            circuit: c,
            inputs: vec!["a".into(), "0".into(), "missing".into()],
            outputs: vec!["a".into()],
        };
        assert_eq!(d.ports(), vec!["a".to_string()]);
    }
}
