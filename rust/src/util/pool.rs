//! Tiny scoped parallel-map built on std::thread::scope.
//!
//! rayon is not in the offline crate cache; the coordinator and the
//! segmented SPICE scheduler only need a static work-split map, which
//! std::thread::scope provides without unsafe.

/// Parallel map over `items` with up to `workers` OS threads.
/// Results are returned in input order. Panics in workers propagate.
pub fn par_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = workers.max(1).min(items.len().max(1));
    if workers <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<&mut Option<R>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                **slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker missed slot")).collect()
}

/// Recommended worker count for this host.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = par_map(&xs, 4, |x| x * 2);
        assert_eq!(ys, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker() {
        let xs = vec![1, 2, 3];
        assert_eq!(par_map(&xs, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, 4, |x| *x).is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let xs = vec![5];
        assert_eq!(par_map(&xs, 16, |x| x * x), vec![25]);
    }
}
