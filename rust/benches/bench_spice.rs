//! SPICE solver scaling — MNA solve cost vs system size for the two
//! elimination orderings and the dense fallback (supports §Perf and the
//! Fig 7 mechanism analysis: Natural ordering goes superlinear on
//! monolithic crossbars; Smart stays near-linear), plus the
//! factor-once/solve-many engine: a sweep/Newton-style repeated-solve
//! workload (same topology, new source values every iteration) comparing
//! the seed per-call `solve_with_stats` path against cached re-solves,
//! and the dense-kernel backends head-to-head (scalar reference vs the
//! portable-SIMD lane-blocked kernels) on the cached multi-RHS resolve.
//!
//!   cargo bench --bench bench_spice
//!
//! Appends a run record (rows + cached-vs-cold and simd-vs-scalar
//! speedups) to BENCH_spice.json at the repo root. `MEMX_BENCH_QUICK=1`
//! runs only the backend head-to-head and *asserts* the SIMD backend has
//! not regressed more than 10% vs scalar — the CI perf smoke.

use std::sync::Arc;

use memx::backend;
use memx::spice::factor::{self, Numeric};
use memx::spice::krylov::SolverStrategy;
use memx::spice::solve::{solve_dense, Ordering, SparseSys};
use memx::spice::{synthetic_crossbar_circuit, Circuit, Element};
use memx::util::bench::{append_json_report, black_box, Bench};
use memx::util::prng::Rng;

/// Programming-noise-style value drift on the memristor stamps: changes
/// matrix *values* (not pattern), so the direct engine must refactor while
/// warm GMRES re-solves off the stale cached LU.
fn drift_values(c: &mut Circuit, rm_idx: &[usize], k: usize) {
    for (d, &i) in rm_idx.iter().enumerate() {
        if let Element::Resistor(_, _, _, r) = &mut c.elements[i] {
            *r *= 1.0 + 1e-4 * ((d + k) as f64 * 0.37).sin();
        }
    }
}

/// Dense baseline, sparse orderings on crossbar MNA systems, and the
/// block-diagonal (segmented limit case) raw sparse system.
fn scaling_sections(b: &mut Bench) {
    let mut rng = Rng::new(31);

    // dense baseline on small systems
    for &n in &[32usize, 96, 192] {
        let mut a = vec![vec![0.0; n]; n];
        let mut bb = vec![0.0; n];
        for i in 0..n {
            for _ in 0..4 {
                a[i][rng.below(n)] += rng.range_f64(-1.0, 1.0);
            }
            a[i][i] += 4.0;
            bb[i] = rng.range_f64(-1.0, 1.0);
        }
        b.run(&format!("dense LU n={n}"), || {
            black_box(solve_dense(&a, &bb).unwrap());
        });
    }

    // sparse orderings on crossbar MNA systems (per-call reference engine)
    for &(inputs, cols) in &[(128usize, 32usize), (256, 64), (512, 128)] {
        let circuit = synthetic_crossbar_circuit(inputs, cols, 100.0, 31 ^ inputs as u64);
        for ord in [Ordering::Smart, Ordering::Natural] {
            b.run(&format!("mna {inputs}x{cols} {ord:?} reference"), || {
                black_box(circuit.dc_op_stats_reference(ord).unwrap());
            });
        }
    }

    // raw sparse system: block-diagonal (segmented limit case)
    for &blocks in &[200usize, 800] {
        let n = blocks * 3;
        let mut s = SparseSys::new(n);
        for k in 0..blocks {
            let i = 3 * k;
            for d in 0..3 {
                s.add(i + d, i + d, 4.0 + d as f64);
            }
            s.add(i, i + 1, 1.0);
            s.add(i + 1, i + 2, 1.0);
            s.add(i + 2, i, 0.5);
            s.add_b(i, 1.0);
        }
        b.run(&format!("block-diag {blocks}x3"), || {
            black_box(s.solve().unwrap());
        });
    }
}

/// Factor-once/solve-many: sweep/Newton style — same topology every
/// iteration, new source values (RHS-only edits). Cold = the seed per-call
/// reference elimination; cached = the factored engine reusing the
/// symbolic factorization (pure re-solves at O(nnz(L+U))).
fn factor_once_sections(b: &mut Bench, derived: &mut Vec<(String, f64)>) {
    for &(inputs, cols) in &[(128usize, 32usize), (256, 64), (512, 128)] {
        let mut circuit = synthetic_crossbar_circuit(inputs, cols, 100.0, 33 ^ inputs as u64);
        let vidx: Vec<usize> = (0..inputs)
            .map(|r| circuit.vsource_index(&format!("V{r}")).unwrap())
            .collect();
        let mut point = 0usize;
        let bump = |c: &mut Circuit, k: usize| {
            for (r, &i) in vidx.iter().enumerate() {
                c.set_vsource_at(i, ((r * 7 + k) as f64 * 0.13).sin() * 0.3).unwrap();
            }
        };
        let cold = b.run(&format!("sweep {inputs}x{cols} cold reference"), || {
            point += 1;
            bump(&mut circuit, point);
            black_box(circuit.dc_op_stats_reference(Ordering::Smart).unwrap());
        });
        let warm = b.run(&format!("sweep {inputs}x{cols} cached resolve"), || {
            point += 1;
            bump(&mut circuit, point);
            black_box(circuit.dc_op().unwrap());
        });
        let speedup = cold.median.as_secs_f64() / warm.median.as_secs_f64().max(1e-12);
        println!("    -> cached-resolve median speedup {speedup:.1}x");
        derived.push((format!("sweep_{inputs}x{cols}_median_speedup"), speedup));
    }
}

/// spice::krylov — iterative vs direct on monolithic systems. Two
/// workloads per size: (a) value drift — direct must refactor every
/// point, warm GMRES reuses the stale complete LU as preconditioner
/// with no refactorization; (b) RHS-only sweep served from the cached
/// ILU(0) pattern. Iteration counts, final residuals, preconditioner
/// reuse hits and per-strategy peak entries land in `derived`
/// (BENCH_spice.json schema).
fn krylov_sections(b: &mut Bench, derived: &mut Vec<(String, f64)>) {
    let iterative = SolverStrategy::Iterative { restart: 24, tol: 1e-11, max_iter: 600 };
    for &(inputs, cols) in &[(256usize, 64usize), (512, 128)] {
        let mut direct_c = synthetic_crossbar_circuit(inputs, cols, 100.0, 35 ^ inputs as u64);
        direct_c.set_solver(SolverStrategy::Direct);
        let rm_idx: Vec<usize> = direct_c
            .elements
            .iter()
            .enumerate()
            .filter(|&(_, e)| matches!(e, Element::Resistor(n, ..) if n.starts_with("RM")))
            .map(|(i, _)| i)
            .collect();
        let mut warm_c = direct_c.clone();
        let mut sweep_c = direct_c.clone();

        let mut point = 0usize;
        let mut peak_direct = 0usize;
        let dstats = b.run(&format!("drift {inputs}x{cols} direct refactor"), || {
            point += 1;
            drift_values(&mut direct_c, &rm_idx, point);
            let (x, st) = direct_c.dc_op_stats(Ordering::Smart).unwrap();
            peak_direct = st.peak_entries;
            black_box(x);
        });

        warm_c.dc_op().unwrap(); // prime the complete LU once
        warm_c.set_solver(iterative);
        let mut point = 0usize;
        let mut warm_iters = 0usize;
        let mut reuse_hits = 0usize;
        let mut worst_res = 0f64;
        let wstats = b.run(&format!("drift {inputs}x{cols} warm gmres cached-lu"), || {
            point += 1;
            drift_values(&mut warm_c, &rm_idx, point);
            let (x, st) = warm_c.dc_op_stats(Ordering::Smart).unwrap();
            warm_iters += st.iterations;
            reuse_hits += st.precond_reused as usize;
            worst_res = worst_res.max(st.residual);
            black_box(x);
        });
        let warm_speedup = dstats.median.as_secs_f64() / wstats.median.as_secs_f64().max(1e-12);
        println!(
            "    -> warm gmres {:.1}x vs refactor; {:.1} iters/solve, {} reuse hits",
            warm_speedup,
            warm_iters as f64 / wstats.iters.max(1) as f64,
            reuse_hits
        );

        sweep_c.set_solver(iterative);
        let vidx: Vec<usize> = (0..inputs)
            .map(|r| sweep_c.vsource_index(&format!("V{r}")).unwrap())
            .collect();
        let mut point = 0usize;
        let mut sweep_iters = 0usize;
        let mut peak_gmres = 0usize;
        let sstats = b.run(&format!("sweep {inputs}x{cols} gmres cached ilu0"), || {
            point += 1;
            for (r, &i) in vidx.iter().enumerate() {
                sweep_c
                    .set_vsource_at(i, ((r * 7 + point) as f64 * 0.13).sin() * 0.3)
                    .unwrap();
            }
            let (x, st) = sweep_c.dc_op_stats(Ordering::Smart).unwrap();
            sweep_iters += st.iterations;
            peak_gmres = st.peak_entries;
            worst_res = worst_res.max(st.residual);
            black_box(x);
        });

        let tag = format!("mono_{inputs}x{cols}");
        derived.push((format!("{tag}_warm_gmres_vs_refactor_speedup"), warm_speedup));
        derived.push((
            format!("{tag}_warm_iters_per_solve"),
            warm_iters as f64 / wstats.iters.max(1) as f64,
        ));
        derived.push((format!("{tag}_precond_reuse_hits"), reuse_hits as f64));
        derived.push((
            format!("{tag}_sweep_iters_per_solve"),
            sweep_iters as f64 / sstats.iters.max(1) as f64,
        ));
        derived.push((format!("{tag}_gmres_worst_relres"), worst_res));
        derived.push((format!("{tag}_peak_entries_direct"), peak_direct as f64));
        derived.push((format!("{tag}_peak_entries_gmres"), peak_gmres as f64));
    }
}

/// Dense-kernel backends head-to-head on the batched cached-resolve path:
/// factor once, then multi-RHS forward/backward substitution (the batched
/// crossbar read inner loop) under the scalar reference and the
/// portable-SIMD lane-blocked kernels. Records `*_simd_speedup` derived
/// fields; in quick mode asserts the SIMD backend has not regressed more
/// than 10% vs scalar on any size.
fn backend_sections(b: &mut Bench, derived: &mut Vec<(String, f64)>, quick: bool) {
    let mut rng = Rng::new(41);
    let sizes: &[(usize, usize)] = if quick { &[(768, 16)] } else { &[(768, 16), (1536, 32)] };
    for &(n, k) in sizes {
        let mut sys = SparseSys::new(n);
        for i in 0..n {
            sys.add(i, i, 5.0 + rng.f64());
            for _ in 0..4 {
                let j = rng.below(n);
                if i != j {
                    sys.add(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let sym = Arc::new(factor::analyze(&sys, Ordering::Smart).unwrap());
        let mut num = Numeric::new(sym);
        num.assemble(&sys).unwrap();
        num.refactor().unwrap();
        let rhss: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let scalar = b.run(&format!("multi-rhs resolve n={n} k={k} scalar"), || {
            black_box(num.solve_multi_kern(&rhss, backend::scalar()).unwrap());
        });
        let simd = b.run(&format!("multi-rhs resolve n={n} k={k} simd"), || {
            black_box(num.solve_multi_kern(&rhss, backend::simd()).unwrap());
        });
        let speedup = scalar.median.as_secs_f64() / simd.median.as_secs_f64().max(1e-12);
        println!("    -> simd multi-RHS speedup {speedup:.2}x");
        derived.push((format!("multi_rhs_n{n}_k{k}_simd_speedup"), speedup));
        if quick {
            assert!(
                speedup >= 0.9,
                "simd backend regressed >10% vs scalar on the cached multi-RHS \
                 resolve (n={n}, k={k}): {speedup:.2}x"
            );
        }
    }
}

/// telemetry overhead contract on the cached multi-RHS resolve (the
/// hottest instrumented kernel): the same workload is timed with tracing
/// off and with tracing fully enabled. Enabled overhead < 2% subsumes the
/// disabled (`Level::Off`) contract, which is one relaxed atomic load per
/// span site. Compared on min-of-iters (noise-robust); quick mode asserts.
fn span_overhead_section(b: &mut Bench, derived: &mut Vec<(String, f64)>, quick: bool) {
    use memx::telemetry::{self, Level};

    let (n, k) = (768usize, 16usize);
    let mut rng = Rng::new(43);
    let mut sys = SparseSys::new(n);
    for i in 0..n {
        sys.add(i, i, 5.0 + rng.f64());
        for _ in 0..4 {
            let j = rng.below(n);
            if i != j {
                sys.add(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    let sym = Arc::new(factor::analyze(&sys, Ordering::Smart).unwrap());
    let mut num = Numeric::new(sym);
    num.assemble(&sys).unwrap();
    num.refactor().unwrap();
    let rhss: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect())
        .collect();

    telemetry::set_level(Level::Off);
    let off = b.run(&format!("multi-rhs resolve n={n} k={k} spans off"), || {
        black_box(num.solve_multi_kern(&rhss, backend::simd()).unwrap());
    });
    telemetry::set_level(Level::Spans);
    let on = b.run(&format!("multi-rhs resolve n={n} k={k} spans on"), || {
        black_box(num.solve_multi_kern(&rhss, backend::simd()).unwrap());
    });
    telemetry::set_level(Level::Off);
    let events = telemetry::drain().len();
    telemetry::clear();

    let frac = on.min.as_secs_f64() / off.min.as_secs_f64().max(1e-12) - 1.0;
    println!(
        "    -> span overhead {:.3}% on the cached multi-RHS resolve \
         ({events} events collected while enabled)",
        frac * 100.0
    );
    derived.push(("span_overhead_frac".into(), frac));
    if quick {
        assert!(events > 0, "enabled tracing recorded no spans on the instrumented kernel");
        assert!(
            frac < 0.02,
            "telemetry span overhead exceeded 2% on the cached multi-RHS resolve \
             (n={n}, k={k}): {:.3}%",
            frac * 100.0
        );
    }
}

fn main() {
    let quick = std::env::var("MEMX_BENCH_QUICK").is_ok();
    let mut b = Bench::quick();
    let mut derived: Vec<(String, f64)> = Vec::new();

    if !quick {
        scaling_sections(&mut b);
        factor_once_sections(&mut b, &mut derived);
        krylov_sections(&mut b, &mut derived);
    }
    backend_sections(&mut b, &mut derived, quick);
    span_overhead_section(&mut b, &mut derived, quick);

    b.table("SPICE solver scaling");
    match append_json_report("BENCH_spice.json", "bench_spice", &b.rows, &derived) {
        Ok(()) => println!("\nrecorded trajectory entry in BENCH_spice.json"),
        Err(e) => eprintln!("\nwarning: could not write BENCH_spice.json: {e}"),
    }
}
