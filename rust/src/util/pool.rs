//! Tiny scoped parallel primitives built on std::thread::scope.
//!
//! rayon is not in the offline crate cache; the coordinator and the
//! segmented SPICE scheduler only need a static work-split map
//! ([`par_map`]/[`par_map_mut`]) and a streamed stage chain
//! ([`pipeline_stream`]), which std::thread::scope provides without
//! unsafe. Nested map calls share a process-wide worker budget
//! ([`set_thread_budget`]) so an outer fan-out that itself fans out does
//! not oversubscribe the host.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide worker budget for [`par_map`]/[`par_map_mut`].
/// 0 means "auto": [`default_workers`]. See [`set_thread_budget`].
static BUDGET: AtomicUsize = AtomicUsize::new(0);
/// Workers currently leased to in-flight [`par_map`]/[`par_map_mut`] calls.
static IN_FLIGHT: AtomicUsize = AtomicUsize::new(0);

/// Cap the total number of worker threads that [`par_map`] and
/// [`par_map_mut`] may have running at once, process-wide. `0` restores
/// the default (one budget's worth per host core). Nested calls — the
/// batched-solve shape where an outer per-segment `par_map_mut` fans out
/// into per-RHS `par_map` workers — are the reason this exists: each call
/// leases workers from the shared budget and inner calls degrade toward
/// serial instead of oversubscribing the host `outer × inner` threads.
///
/// Every call is always granted at least one worker (the serial inline
/// path), so progress never blocks on the budget. [`pipeline_stream`] is
/// deliberately exempt: its groups communicate through capacity-1
/// rendezvous channels and capping them would deadlock the chain.
pub fn set_thread_budget(n: usize) {
    BUDGET.store(n, Ordering::Relaxed);
}

/// The effective process-wide worker budget ([`set_thread_budget`], with
/// 0 resolving to [`default_workers`]).
pub fn thread_budget() -> usize {
    match BUDGET.load(Ordering::Relaxed) {
        0 => default_workers(),
        n => n,
    }
}

/// A lease of worker slots against the global budget; returned to the
/// pool on drop (including on panic unwind out of a worker scope).
struct Lease(usize);

impl Lease {
    /// Grant `min(want, budget - in_flight)`, but never less than 1:
    /// a saturated budget degrades callers to the serial path rather
    /// than blocking them.
    fn take(want: usize) -> Lease {
        let budget = thread_budget();
        loop {
            let used = IN_FLIGHT.load(Ordering::Relaxed);
            let grant = want.min(budget.saturating_sub(used)).max(1);
            if IN_FLIGHT
                .compare_exchange(used, used + grant, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return Lease(grant);
            }
        }
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        IN_FLIGHT.fetch_sub(self.0, Ordering::Relaxed);
    }
}

/// Parallel map over `items` with up to `workers` OS threads (further
/// capped by the global [`set_thread_budget`] lease).
/// Results are returned in input order. Panics in workers propagate.
pub fn par_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = workers.max(1).min(items.len().max(1));
    if workers <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let lease = Lease::take(workers);
    let workers = lease.0;
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<&mut Option<R>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                **slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker missed slot")).collect()
}

/// Parallel map over mutable items (e.g. per-segment circuits whose cached
/// factorizations update during the solve). Items are split into contiguous
/// chunks, one worker per chunk (capped by the global [`set_thread_budget`]
/// lease); results return in input order. Panics in workers propagate.
pub fn par_map_mut<T, R, F>(items: &mut [T], workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(&mut T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter_mut().map(f).collect();
    }
    let lease = Lease::take(workers);
    let workers = lease.0;
    if workers <= 1 {
        return items.iter_mut().map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Vec<R>> = Vec::new();
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = items
            .chunks_mut(chunk)
            .map(|ch| s.spawn(move || ch.iter_mut().map(f).collect::<Vec<R>>()))
            .collect();
        out = handles
            .into_iter()
            .map(|h| h.join().expect("par_map_mut worker panicked"))
            .collect();
    });
    out.into_iter().flatten().collect()
}

/// Recommended worker count for this host.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Streamed pipeline over a chain of stage groups — the §5.2-style
/// overlapped schedule: each group runs on its own scoped thread, items
/// flow group-to-group through capacity-1 rendezvous channels (a
/// double-buffered hand-off: a group works on item k while item k+1 waits
/// in its mailbox), so group N processes item k concurrently with group
/// N+1 processing item k−1.
///
/// Items are returned in input order. On the first `Err` the failing item
/// stops flowing, upstream groups unwind (their sends fail once the chain
/// collapses), and that error is returned; items already past the failure
/// point are discarded. Panics in group threads propagate. An empty group
/// chain returns the items untouched.
pub fn pipeline_stream<G, T, E, F>(groups: Vec<G>, inputs: Vec<T>, run: F) -> Result<Vec<T>, E>
where
    G: Send,
    T: Send,
    E: Send,
    F: Fn(&mut G, T) -> Result<T, E> + Sync,
{
    if groups.is_empty() {
        return Ok(inputs);
    }
    let n = inputs.len();
    let mut out: Vec<T> = Vec::with_capacity(n);
    let mut failure: Option<E> = None;
    std::thread::scope(|s| {
        let run = &run;
        let mut rx_prev: Option<std::sync::mpsc::Receiver<Result<T, E>>> = None;
        let mut feed = Some(inputs);
        for mut group in groups {
            // capacity 1: one item in flight per hand-off buffer
            let (tx, rx_next) = std::sync::mpsc::sync_channel::<Result<T, E>>(1);
            let rx_in = rx_prev.take();
            let feed_items = if rx_in.is_none() { feed.take() } else { None };
            s.spawn(move || match rx_in {
                // head group: feeds the input items into the chain
                None => {
                    for item in feed_items.expect("head group owns the inputs") {
                        let r = run(&mut group, item);
                        let failed = r.is_err();
                        if tx.send(r).is_err() || failed {
                            break;
                        }
                    }
                }
                // interior/tail groups: drain the upstream mailbox
                Some(rx) => {
                    for msg in rx {
                        let r = match msg {
                            Ok(item) => run(&mut group, item),
                            Err(e) => Err(e),
                        };
                        let failed = r.is_err();
                        if tx.send(r).is_err() || failed {
                            break;
                        }
                    }
                }
            });
            rx_prev = Some(rx_next);
        }
        let rx_last = rx_prev.take().expect("non-empty group chain");
        while let Ok(msg) = rx_last.recv() {
            match msg {
                Ok(item) => out.push(item),
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        // dropping the tail receiver unblocks any upstream sender so the
        // scope can join after an early error
        drop(rx_last);
    });
    match failure {
        Some(e) => Err(e),
        None => {
            debug_assert_eq!(out.len(), n, "every item must flow through the chain");
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = par_map(&xs, 4, |x| x * 2);
        assert_eq!(ys, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker() {
        let xs = vec![1, 2, 3];
        assert_eq!(par_map(&xs, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, 4, |x| *x).is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let xs = vec![5];
        assert_eq!(par_map(&xs, 16, |x| x * x), vec![25]);
    }

    #[test]
    fn par_map_mut_updates_and_orders() {
        let mut xs: Vec<u64> = (0..57).collect();
        let ys = par_map_mut(&mut xs, 4, |x| {
            *x += 1;
            *x * 10
        });
        assert_eq!(xs, (1..=57).collect::<Vec<_>>());
        assert_eq!(ys, (1..=57).map(|x| x * 10).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_mut_single_and_empty() {
        let mut xs: Vec<u32> = vec![];
        assert!(par_map_mut(&mut xs, 4, |x| *x).is_empty());
        let mut one = vec![7u32];
        assert_eq!(par_map_mut(&mut one, 8, |x| *x + 1), vec![8]);
    }

    #[test]
    fn thread_budget_caps_nested_parallelism() {
        // 4 outer workers each wanting 6 inner workers would put 24 leaf
        // closures in flight unbudgeted; with a budget of 3 the outer map
        // leases 3 workers and every inner call degrades to the serial
        // path, so at most 3 leaf closures ever run concurrently.
        set_thread_budget(3);
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let outer: Vec<u64> = (0..4).collect();
        let got = par_map(&outer, 4, |&o| {
            let inner: Vec<u64> = (0..6).collect();
            par_map(&inner, 6, |&i| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(2));
                live.fetch_sub(1, Ordering::SeqCst);
                o * 10 + i
            })
            .into_iter()
            .sum::<u64>()
        });
        set_thread_budget(0);
        let want: Vec<u64> =
            outer.iter().map(|o| (0..6).map(|i| o * 10 + i).sum()).collect();
        assert_eq!(got, want);
        let peak = peak.load(Ordering::SeqCst);
        assert!(peak <= 3, "peak concurrency {peak} exceeded budget 3");
    }

    #[test]
    fn pipeline_stream_orders_and_applies_all_groups() {
        // three stage groups, each with its own state, applied in chain
        // order to every item; results must come back in input order
        let groups: Vec<(u64, u64)> = vec![(1, 0), (10, 0), (100, 0)];
        let items: Vec<u64> = (0..17).collect();
        let got = pipeline_stream(groups, items.clone(), |g, x| {
            g.1 += 1; // per-group call counter (exclusive &mut state)
            Ok::<u64, ()>(x + g.0)
        })
        .unwrap();
        assert_eq!(got, items.iter().map(|x| x + 111).collect::<Vec<_>>());
    }

    #[test]
    fn pipeline_stream_empty_chain_and_empty_items() {
        let none: Vec<u32> = vec![];
        assert_eq!(
            pipeline_stream(Vec::<u8>::new(), vec![1u32, 2], |_, x| Ok::<u32, ()>(x)).unwrap(),
            vec![1, 2]
        );
        assert!(pipeline_stream(vec![0u8], none, |_, x| Ok::<u32, ()>(x))
            .unwrap()
            .is_empty());
    }

    #[test]
    fn pipeline_stream_propagates_first_error_and_joins() {
        // middle group fails on item 3: the error must surface, and all
        // threads must unwind (scope join) without deadlock
        let groups: Vec<usize> = vec![0, 1, 2];
        let items: Vec<u64> = (0..50).collect();
        let err = pipeline_stream(groups, items, |g, x| {
            if *g == 1 && x == 3 {
                Err(format!("boom at {x}"))
            } else {
                Ok(x)
            }
        })
        .unwrap_err();
        assert_eq!(err, "boom at 3");
    }
}
