//! Backend-parity suite: pins the portable-SIMD kernels against the
//! scalar reference on every dense hot loop the `memx::backend` trait
//! covers — multi-RHS LU substitution (bit-identical by contract), ILU(0)
//! triangular sweeps (shared reference code, bit-identical), GMRES
//! (reduction kernels reassociate, so parity is pinned to ≤1e-12 relative
//! on well-conditioned MNA-like systems), and the full demo-network chain
//! at `Fidelity::Spice` under both backends.

use std::sync::Arc;

use memx::backend::{self, BackendChoice};
use memx::netlist::CrossbarSim;
use memx::pipeline::{default_device, demo_network, Fidelity, PipelineBuilder};
use memx::spice::factor::{self, Numeric};
use memx::spice::krylov::{self, Ilu0, KrylovCfg};
use memx::spice::solve::{Ordering, SparseSys};
use memx::util::prng::Rng;
use memx::util::prop::check;

/// A random MNA-like system: strong 5.0-ish diagonal plus a few unit-scale
/// couplings per row (strictly diagonally dominant, so both the direct
/// factorization and ILU(0)-preconditioned GMRES are well behaved). With
/// `zero_diag_pair`, rows 0/1 instead carry only an anti-diagonal entry
/// pair, forcing the eliminator through an off-diagonal pivot.
fn mna_system(rng: &mut Rng, n: usize, zero_diag_pair: bool) -> SparseSys {
    let mut sys = SparseSys::new(n);
    let pair = zero_diag_pair && n >= 2;
    let start = if pair { 2 } else { 0 };
    if pair {
        sys.add(0, 1, 2.0 + rng.f64());
        sys.add(1, 0, 2.0 + rng.f64());
    }
    for i in start..n {
        sys.add(i, i, 5.0 + rng.f64());
    }
    for i in 0..n {
        for _ in 0..3 {
            let j = rng.below(n);
            // keep the anti-diagonal block isolated so it stays nonsingular
            if pair && (i < 2 || j < 2) {
                continue;
            }
            if i != j {
                sys.add(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
        sys.add_b(i, rng.range_f64(-1.0, 1.0));
    }
    sys
}

fn factor_sys(sys: &SparseSys) -> Numeric {
    let sym = Arc::new(factor::analyze(sys, Ordering::Smart).expect("symbolic analysis"));
    let mut num = Numeric::new(sym);
    num.assemble(sys).expect("assemble");
    num.refactor().expect("refactor");
    num
}

fn ilu(sys: &SparseSys) -> Ilu0 {
    let mut p = Ilu0::analyze(sys).expect("ilu analyze");
    p.assemble(sys).expect("ilu assemble");
    p.factor().expect("ilu factor");
    p
}

fn rhs_batch(rng: &mut Rng, n: usize, k: usize) -> Vec<Vec<f64>> {
    (0..k).map(|_| (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect()).collect()
}

/// Tight tolerance so reassociation-induced GMRES differences stay well
/// inside the 1e-12 parity gate.
fn tight_cfg() -> KrylovCfg {
    KrylovCfg { restart: 64, tol: 1e-13, max_iter: 2000 }
}

fn rel_close(a: &[f64], b: &[f64], tol: f64) -> bool {
    let scale = a.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol * scale)
}

#[test]
fn multi_rhs_substitution_bit_identical_across_backends() {
    check(
        "multi-rhs-backend-parity",
        40,
        |rng: &mut Rng, size: usize| {
            let n = 2 + rng.below(3 * size + 6);
            let pair = rng.below(3) == 0; // every ~third case pivots 0/1
            let sys = mna_system(rng, n, pair);
            let k = 1 + rng.below(18); // spans every SIMD lane width 8/4/2/1
            let rhss = rhs_batch(rng, n, k);
            (sys, rhss)
        },
        |(sys, rhss)| {
            let num = factor_sys(sys);
            let xs = num.solve_multi_kern(rhss, backend::scalar()).expect("scalar solve");
            let ys = num.solve_multi_kern(rhss, backend::simd()).expect("simd solve");
            // bit-identical by contract: the SIMD lane blocks replay the
            // scalar per-pivot operation order exactly
            xs == ys
        },
    );
}

#[test]
fn zero_diagonal_pivot_pair_parity() {
    let mut rng = Rng::new(0xA171);
    let sys = mna_system(&mut rng, 9, true);
    let num = factor_sys(&sys);
    let rhss = rhs_batch(&mut rng, 9, 11);
    let xs = num.solve_multi_kern(&rhss, backend::scalar()).unwrap();
    let ys = num.solve_multi_kern(&rhss, backend::simd()).unwrap();
    assert_eq!(xs, ys);
    // the single-RHS path agrees with the batched columns
    for (k, rhs) in rhss.iter().enumerate() {
        let x1 = num.solve_kern(rhs, backend::simd()).unwrap();
        assert!(rel_close(&xs[k], &x1, 1e-12), "column {k} disagrees with single-RHS solve");
    }
}

#[test]
fn ilu0_sweep_bit_identical_across_backends() {
    check(
        "ilu0-backend-parity",
        30,
        |rng: &mut Rng, size: usize| {
            let n = 2 + rng.below(3 * size + 6);
            let sys = mna_system(rng, n, false);
            let r: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            (sys, r)
        },
        |(sys, r)| {
            let pre = ilu(sys);
            let a = pre.solve_kern(r, backend::scalar()).expect("scalar sweep");
            let b = pre.solve_kern(r, backend::simd()).expect("simd sweep");
            a == b // the sweep itself is shared reference code
        },
    );
}

#[test]
fn gmres_parity_within_1e12_on_mna_systems() {
    check(
        "gmres-backend-parity",
        25,
        |rng: &mut Rng, size: usize| {
            let n = 3 + rng.below(3 * size + 8);
            mna_system(rng, n, false)
        },
        |sys| {
            let pre = ilu(sys);
            let cfg = tight_cfg();
            let (xs, st_s) =
                krylov::gmres_kern(sys, &sys.b, &pre, &cfg, backend::scalar()).expect("scalar");
            let (xv, st_v) =
                krylov::gmres_kern(sys, &sys.b, &pre, &cfg, backend::simd()).expect("simd");
            st_s.backend == "scalar" && st_v.backend == "simd" && rel_close(&xs, &xv, 1e-12)
        },
    );
}

#[test]
fn gmres_batch_parity_and_backend_attribution() {
    let mut rng = Rng::new(0x6B47);
    let sys = mna_system(&mut rng, 40, false);
    let rhss = rhs_batch(&mut rng, 40, 6);
    let pre = ilu(&sys);
    let cfg = tight_cfg();
    let (xs, st_s) =
        krylov::gmres_batch_kern(&sys, &rhss, &pre, &cfg, 2, backend::scalar()).unwrap();
    let (xv, st_v) =
        krylov::gmres_batch_kern(&sys, &rhss, &pre, &cfg, 2, backend::simd()).unwrap();
    assert_eq!(st_s.backend, "scalar");
    assert_eq!(st_v.backend, "simd");
    for (k, (a, b)) in xs.iter().zip(&xv).enumerate() {
        assert!(rel_close(a, b, 1e-12), "batch column {k} exceeded 1e-12 relative parity");
    }
}

#[test]
fn crossbar_sim_batch_identical_across_backends() {
    let dev = default_device();
    let cb = memx::mapper::build_synthetic_fc(
        10,
        6,
        dev.levels,
        memx::mapper::MapMode::Inverted,
        0xCB5,
    );
    let mut rng = Rng::new(0xCB51);
    let inputs: Vec<Vec<f64>> =
        (0..8).map(|_| (0..10).map(|_| rng.range_f64(-0.4, 0.4)).collect()).collect();
    let mut solve = |choice: BackendChoice| {
        let mut sim = CrossbarSim::new(
            &cb,
            &dev,
            4,
            Ordering::Smart,
            memx::spice::krylov::SolverStrategy::Auto,
        )
        .unwrap();
        sim.set_backend(choice);
        sim.solve_batch(&inputs, 2).unwrap()
    };
    let a = solve(BackendChoice::Scalar);
    let b = solve(BackendChoice::Simd);
    assert_eq!(a, b, "direct-path crossbar reads must be bit-identical across backends");
}

#[test]
fn demo_network_spice_agrees_across_backends() {
    let (m, ws) = demo_network(7).unwrap();
    let mut build = |choice: BackendChoice| {
        PipelineBuilder::new()
            .fidelity(Fidelity::Spice)
            .segment(8)
            .backend(choice)
            .build(&m, &ws)
            .unwrap()
    };
    let mut scalar_pipe = build(BackendChoice::Scalar);
    let mut simd_pipe = build(BackendChoice::Simd);
    let mut rng = Rng::new(0xBACC);
    let x: Vec<f64> =
        (0..scalar_pipe.in_dim()).map(|_| rng.range_f64(-0.5, 0.5)).collect();
    let a = scalar_pipe.forward(&x).unwrap();
    let b = simd_pipe.forward(&x).unwrap();
    assert!(
        rel_close(&a, &b, 1e-9),
        "full-chain spice logits diverged across backends: {a:?} vs {b:?}"
    );
}

#[test]
fn backend_choice_cli_contract() {
    assert_eq!("scalar".parse::<BackendChoice>().unwrap(), BackendChoice::Scalar);
    assert_eq!("simd".parse::<BackendChoice>().unwrap(), BackendChoice::Simd);
    assert_eq!("auto".parse::<BackendChoice>().unwrap(), BackendChoice::Auto);
    assert!("gpu".parse::<BackendChoice>().is_err());
    assert_eq!(BackendChoice::Simd.to_string(), "simd");
    assert_eq!(BackendChoice::default(), BackendChoice::Auto);
    assert_eq!(backend::resolve(BackendChoice::Scalar).name(), "scalar");
    assert_eq!(backend::resolve(BackendChoice::Simd).name(), "simd");
}

#[test]
fn kernel_time_counters_accumulate() {
    let mut rng = Rng::new(0x7311);
    let sys = mna_system(&mut rng, 120, false);
    let num = factor_sys(&sys);
    let rhss = rhs_batch(&mut rng, 120, 32);
    let before = backend::subst_ns();
    num.solve_multi_kern(&rhss, backend::simd()).unwrap();
    assert!(
        backend::subst_ns() > before,
        "a 120x32 substitution pass must land in the process-wide kernel-time counter"
    );
    let pre = ilu(&sys);
    let matvec_before = backend::matvec_ns();
    let (_, st) = krylov::gmres_kern(&sys, &sys.b, &pre, &tight_cfg(), backend::simd()).unwrap();
    assert_eq!(st.backend, "simd");
    assert!(backend::matvec_ns() >= matvec_before + st.matvec_ns);
}
