//! Serving-tier tests over stub executors — no artifacts, no PJRT: the
//! ungated `coordinator::Server` queue + batcher thread is driven end to
//! end, including its behavior under a deliberately slow executor (queue
//! latency, waited-out partial batches) and error propagation.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};
use memx::coordinator::{
    ExecuteError, InferenceExecutor, PipelineExecutor, RecalPolicy, Server,
};
use memx::fault::{FaultConfig, FaultModel};
use memx::pipeline::{default_device, Fidelity, PipelineBuilder, StageStat};

/// A deterministic stub backend: label = floor(first pixel * classes),
/// optional fixed delay per batch, optional injected failure. The struct is
/// `Send`, so tests build it, keep clones of its counters, and move it into
/// the server's executor factory.
struct StubExec {
    img_elems: usize,
    classes: usize,
    batches: Vec<usize>,
    delay: Duration,
    fail: bool,
    calls: Arc<AtomicU64>,
    served_batch_sizes: Arc<Mutex<Vec<usize>>>,
}

impl StubExec {
    fn new(img_elems: usize, classes: usize, batches: &[usize], delay: Duration) -> StubExec {
        StubExec {
            img_elems,
            classes,
            batches: batches.to_vec(),
            delay,
            fail: false,
            calls: Arc::new(AtomicU64::new(0)),
            served_batch_sizes: Arc::new(Mutex::new(Vec::new())),
        }
    }
}

impl InferenceExecutor for StubExec {
    fn describe(&self) -> String {
        "stub".into()
    }

    fn img_elems(&self) -> usize {
        self.img_elems
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn available_batches(&self) -> Vec<usize> {
        self.batches.clone()
    }

    fn run_batch(&mut self, images: &[f32]) -> Result<Vec<f32>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let b = images.len() / self.img_elems;
        self.served_batch_sizes.lock().unwrap().push(b);
        if self.fail {
            bail!("stub executor down");
        }
        std::thread::sleep(self.delay);
        let mut logits = vec![0f32; b * self.classes];
        for i in 0..b {
            let label = ((images[i * self.img_elems] * self.classes as f32) as usize)
                .min(self.classes - 1);
            logits[i * self.classes + label] = 1.0;
        }
        Ok(logits)
    }

    fn take_stage_stats(&mut self) -> Vec<StageStat> {
        vec![StageStat { name: "stub-stage".into(), total: self.delay, calls: 1 }]
    }
}

/// image whose stub label is `l` (first pixel encodes the class)
fn img_for(l: usize, classes: usize, img_elems: usize) -> Vec<f32> {
    let mut v = vec![0.0; img_elems];
    v[0] = (l as f32 + 0.5) / classes as f32;
    v
}

#[test]
fn slow_executor_partial_batch_waits_out_and_pads() {
    // one request against a [4]-only executor: the batcher must hold it for
    // max_wait, then dispatch a padded partial batch of 4
    let (img, classes) = (6, 4);
    let max_wait = Duration::from_millis(5);
    let stub = StubExec::new(img, classes, &[4], Duration::from_millis(10));
    let server = Server::start_with(max_wait, move || {
        Ok(Box::new(stub) as Box<dyn InferenceExecutor>)
    })
    .unwrap();
    let client = server.client();
    let t0 = std::time::Instant::now();
    let pred = client.classify(img_for(2, classes, img)).unwrap();
    assert_eq!(pred.label, 2);
    // end-to-end latency covers the deadline wait plus the slow executor
    assert!(t0.elapsed() >= max_wait, "deadline must gate the partial batch");
    assert!(pred.latency >= max_wait);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.batches, 1);
    assert_eq!(snap.padded_slots, 3, "batch of 4 carried 1 real request");
    assert!(snap.queue_mean >= Duration::from_millis(1), "queue wait recorded");
    assert!(snap.exec_busy >= Duration::from_millis(10), "executor busy time recorded");
    // the stub's stage drain lands in the snapshot table
    assert!(snap.stages.iter().any(|s| s.name == "stub-stage"));
    server.shutdown();
}

#[test]
fn slow_executor_accumulates_full_batches_under_load() {
    // a slow executor makes requests pile up; once >= 8 are queued the
    // batcher must prefer the full compiled size over b1 dispatches
    let (img, classes) = (4, 5);
    let n = 24;
    let stub = StubExec::new(img, classes, &[1, 8], Duration::from_millis(4));
    let sizes = stub.served_batch_sizes.clone();
    let server = Server::start_with(Duration::from_millis(2), move || {
        Ok(Box::new(stub) as Box<dyn InferenceExecutor>)
    })
    .unwrap();
    let client = server.client();
    let correct = std::sync::atomic::AtomicUsize::new(0);
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..8 {
            let c = client.clone();
            let correct = &correct;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let want = i % classes;
                if c.classify(img_for(want, classes, img)).unwrap().label == want {
                    correct.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    assert_eq!(correct.load(Ordering::Relaxed), n);
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, n as u64);
    assert_eq!(snap.errors, 0);
    let served = sizes.lock().unwrap().clone();
    assert_eq!(served.iter().sum::<usize>() as u64, snap.completed + snap.padded_slots);
    assert!(
        served.iter().any(|&b| b == 8),
        "8 closed-loop clients against a slow executor must fill a b8 batch at least once: {served:?}"
    );
    assert!(snap.queue_mean > Duration::ZERO);
    server.shutdown();
}

#[test]
fn executor_failure_surfaces_to_clients_and_metrics() {
    let (img, classes) = (3, 2);
    let mut stub = StubExec::new(img, classes, &[1], Duration::ZERO);
    stub.fail = true;
    let server = Server::start_with(Duration::from_millis(1), move || {
        Ok(Box::new(stub) as Box<dyn InferenceExecutor>)
    })
    .unwrap();
    let client = server.client();
    let err = client.classify(vec![0.2; img]).unwrap_err();
    assert!(format!("{err}").contains("stub executor down"), "{err}");
    let snap = server.metrics().snapshot();
    assert_eq!(snap.errors, 1);
    assert_eq!(snap.completed, 0);
    server.shutdown();
}

#[test]
fn server_rejects_malformed_image_offline() {
    let stub = StubExec::new(8, 3, &[1, 2], Duration::ZERO);
    let server = Server::start_with(Duration::from_millis(1), move || {
        Ok(Box::new(stub) as Box<dyn InferenceExecutor>)
    })
    .unwrap();
    let client = server.client();
    assert!(client.classify(vec![0.0; 5]).is_err());
    // well-formed requests still flow afterwards
    assert_eq!(client.classify(img_for(1, 3, 8)).unwrap().label, 1);
    server.shutdown();
}

/// A real [`PipelineExecutor`] behind a test-controlled kill switch: the
/// soak test flips `fail` mid-stream to model an executor that dies and
/// later recovers, while the inner pipeline keeps its drift clock.
struct FlakyPipeline {
    inner: PipelineExecutor,
    fail: Arc<AtomicBool>,
}

impl InferenceExecutor for FlakyPipeline {
    fn describe(&self) -> String {
        format!("flaky {}", self.inner.describe())
    }

    fn img_elems(&self) -> usize {
        self.inner.img_elems()
    }

    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }

    fn available_batches(&self) -> Vec<usize> {
        self.inner.available_batches()
    }

    fn warmup(&mut self) -> Result<()> {
        self.inner.warmup()
    }

    fn run_batch(&mut self, images: &[f32]) -> Result<Vec<f32>> {
        if self.fail.load(Ordering::Relaxed) {
            bail!("injected mid-stream fault");
        }
        self.inner.run_batch(images)
    }

    fn take_stage_stats(&mut self) -> Vec<StageStat> {
        self.inner.take_stage_stats()
    }

    fn recalibrate(&mut self) -> Result<u64> {
        self.inner.recalibrate()
    }
}

#[test]
fn soak_drift_detection_recalibration_and_flaky_executor() {
    // a pipeline executor aging under drift + read disturb + stuck cells,
    // behind a failing-then-recovering wrapper: the server must never
    // deadlock or panic, the watchdog must detect the margin collapse and
    // recalibrate, and per-request errors must carry batch context
    let fail = Arc::new(AtomicBool::new(false));
    let fail2 = fail.clone();
    let policy = RecalPolicy {
        enabled: true,
        ewma_alpha: 0.5,
        warm_batches: 3,
        margin_frac: 0.8,
        cooldown_batches: 3,
    };
    let server = Server::start_with_policy(Duration::from_micros(200), policy, move || {
        let pipeline = PipelineBuilder::new()
            .fidelity(Fidelity::Behavioural)
            .build_fc_stack(&[12, 8, 4], &default_device(), 42)?;
        // read disturb dominates (2% conductance decay per served batch)
        // so the margin EWMA degrades linearly and predictably; the 1%
        // stuck-OFF cells persist across recalibrations
        let cfg = FaultConfig { stuck_off_frac: 0.01, ..FaultConfig::default() };
        let exec = PipelineExecutor::new(pipeline, (2, 2, 3), &[1], 1)?
            .with_faults(FaultModel::new(cfg), 1.0, 2_000_000, 0.0);
        Ok(Box::new(FlakyPipeline { inner: exec, fail: fail2 }) as Box<dyn InferenceExecutor>)
    })
    .unwrap();
    let client = server.client();
    let img: Vec<f32> = (0..12).map(|i| ((i as f32 * 0.17).sin().abs() * 0.5) + 0.1).collect();

    let mut recalibrated = false;
    for _ in 0..300 {
        client.classify(img.clone()).unwrap();
        if server.metrics().snapshot().recalibrations >= 1 {
            recalibrated = true;
            break;
        }
    }
    assert!(recalibrated, "drift watchdog never recalibrated within 300 batches");
    assert!(server.metrics().snapshot().drift_detections >= 1);

    // mid-stream executor death: every queued request gets a structured
    // error naming the failed batch ...
    fail.store(true, Ordering::Relaxed);
    let err = client.classify(img.clone()).unwrap_err();
    let ee = err.downcast_ref::<ExecuteError>().expect("executor failure downcasts to ExecuteError");
    assert!(ee.detail.contains("injected mid-stream fault"), "{ee}");
    assert!(ee.batch >= 1 && ee.batch_size >= 1, "{ee}");

    // ... and service resumes once the backend recovers
    fail.store(false, Ordering::Relaxed);
    let pred = client.classify(img.clone()).unwrap();
    assert!(pred.label < 4);

    let snap = server.metrics().snapshot();
    assert!(snap.errors >= 1);
    assert!(snap.completed >= 2);
    // counters must survive the print path (drift/recal/fallback lines)
    snap.print(Duration::from_secs(1));
    server.shutdown();
}

#[test]
fn warmup_failure_reports_at_start() {
    struct BadWarmup;
    impl InferenceExecutor for BadWarmup {
        fn describe(&self) -> String {
            "bad".into()
        }
        fn img_elems(&self) -> usize {
            1
        }
        fn num_classes(&self) -> usize {
            1
        }
        fn available_batches(&self) -> Vec<usize> {
            vec![1]
        }
        fn warmup(&mut self) -> Result<()> {
            bail!("no device")
        }
        fn run_batch(&mut self, _images: &[f32]) -> Result<Vec<f32>> {
            unreachable!("warmup failed")
        }
    }
    let err = Server::start_with(Duration::from_millis(1), || {
        Ok(Box::new(BadWarmup) as Box<dyn InferenceExecutor>)
    })
    .unwrap_err();
    assert!(format!("{err}").contains("no device"), "{err}");
}
