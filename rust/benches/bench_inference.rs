//! E5/E6/E10 / Fig 8 — inference latency + energy: the pipeline end-to-end
//! batched-forward workload (batch 1 vs 16 vs 64 through
//! `Pipeline::forward_batch`, appended to BENCH_pipeline.json), the serve
//! path (batcher queue + pipelined stage scheduler at workers 1/2/4,
//! appended to BENCH_serve.json), the analytical crossbar models
//! (Eqs 17/18) against the paper's GPU/CPU baselines, and — with the
//! `runtime-xla` feature — the *measured* digital PJRT latency on this
//! host per batch size.
//!
//!   cargo bench --bench bench_inference
//!
//! `MEMX_BENCH_QUICK=1` runs the reduced CI smoke variant: the full-chain
//! spice conformance workload (the demo network with every §3 module
//! circuit-simulated — BN pair, GAP column, conv banks, Fig 4
//! activations — pinned against the behavioural reference) plus the
//! dense-kernel backend head-to-head, which asserts the SIMD backend has
//! not regressed more than 10% vs scalar on the batched spice forward.

use memx::pipeline::{default_device, Fidelity, PipelineBuilder};
use memx::util::bench::{append_json_report, black_box, Bench};
use memx::util::prng::Rng;

/// End-to-end batched pipeline forward: how much a batch amortizes the
/// per-image cost (at SPICE fidelity, batches share one multi-RHS
/// substitution pass per crossbar segment).
fn pipeline_workload() -> anyhow::Result<()> {
    let dev = default_device();
    let dims = [96usize, 96, 48, 10];
    let mut rng = Rng::new(11);
    let inputs: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..dims[0]).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();

    println!("== pipeline end-to-end batched forward (fc {dims:?}) ==");
    let mut b = Bench::quick();
    let mut derived: Vec<(String, f64)> = Vec::new();
    let mut spice_per_image: Vec<(usize, f64)> = Vec::new();
    for fidelity in [Fidelity::Behavioural, Fidelity::Spice] {
        let mut pipe = PipelineBuilder::new()
            .fidelity(fidelity)
            .segment(32)
            .build_fc_stack(&dims, &dev, 3)?;
        for &batch in &[1usize, 16, 64] {
            let chunk = &inputs[..batch];
            let stats = b.run(&format!("pipeline {fidelity} b{batch}"), || {
                black_box(pipe.forward_batch(chunk).expect("forward_batch"));
            });
            let per_image = stats.mean_secs() / batch as f64;
            println!("    -> per-image {:.1} µs", per_image * 1e6);
            if fidelity == Fidelity::Spice {
                spice_per_image.push((batch, per_image));
            }
        }
    }
    if let (Some(&(_, t1)), Some(&(_, t64))) =
        (spice_per_image.first(), spice_per_image.last())
    {
        derived.push(("spice_b64_vs_b1_per_image_speedup".into(), t1 / t64.max(1e-12)));
    }
    b.table("pipeline batched forward");
    match append_json_report("BENCH_pipeline.json", "bench_inference_pipeline", &b.rows, &derived)
    {
        Ok(()) => println!("(appended to BENCH_pipeline.json)"),
        Err(e) => eprintln!("warning: could not append BENCH_pipeline.json: {e}"),
    }
    Ok(())
}

/// Scalar vs portable-SIMD dense kernels on the batched spice forward:
/// same fc stack, same inputs, backend pinned per pipeline via
/// [`PipelineBuilder::backend`]. Records `spice_b{N}_simd_speedup` derived
/// fields in BENCH_pipeline.json; in quick mode (the CI smoke) asserts the
/// SIMD backend has not regressed more than 10% vs scalar.
fn backend_workload(quick: bool) -> anyhow::Result<()> {
    use memx::pipeline::BackendChoice;

    let dev = default_device();
    let dims = [96usize, 96, 48, 10];
    let mut rng = Rng::new(13);
    let inputs: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..dims[0]).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();

    println!("\n== spice batched forward: scalar vs simd dense kernels (fc {dims:?}) ==");
    let mut b = Bench::quick();
    let mut derived: Vec<(String, f64)> = Vec::new();
    let batches: &[usize] = if quick { &[16] } else { &[16, 64] };
    for &batch in batches {
        let chunk = &inputs[..batch];
        let mut medians = Vec::with_capacity(2);
        for backend in [BackendChoice::Scalar, BackendChoice::Simd] {
            let mut pipe = PipelineBuilder::new()
                .fidelity(Fidelity::Spice)
                .segment(32)
                .backend(backend)
                .build_fc_stack(&dims, &dev, 3)?;
            pipe.forward_batch(chunk)?; // cold pass primes the factor caches
            let stats = b.run(&format!("pipeline spice b{batch} {backend}"), || {
                black_box(pipe.forward_batch(chunk).expect("forward_batch"));
            });
            medians.push(stats.median.as_secs_f64());
        }
        let speedup = medians[0] / medians[1].max(1e-12);
        println!("    -> b{batch} simd speedup {speedup:.2}x");
        derived.push((format!("spice_b{batch}_simd_speedup"), speedup));
        if quick {
            assert!(
                speedup >= 0.9,
                "simd backend regressed >10% vs scalar on the spice batched \
                 forward (b{batch}): {speedup:.2}x"
            );
        }
    }
    b.table("spice forward: dense-kernel backends");
    match append_json_report("BENCH_pipeline.json", "bench_inference_backend", &b.rows, &derived)
    {
        Ok(()) => println!("(appended to BENCH_pipeline.json)"),
        Err(e) => eprintln!("warning: could not append BENCH_pipeline.json: {e}"),
    }
    Ok(())
}

/// Serve-path workload: the batcher queue + pipelined stage scheduler end
/// to end over a synthetic pipeline, four closed-loop clients, scheduler
/// workers 1/2/4 — the §5.2 operating point as served throughput.
fn serve_workload() -> anyhow::Result<()> {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use memx::coordinator::{InferenceExecutor, PipelineExecutor, Server};
    use memx::telemetry::{self, Level, Ph};

    let (h, w, c, classes) = (8usize, 8usize, 3usize, 10usize);
    let dims = [h * w * c, 96, 48, classes];
    let n = 256usize;
    let mut rng = Rng::new(23);
    let images: Vec<f32> = (0..n * h * w * c).map(|_| rng.f32()).collect();

    println!("\n== serve path: batcher + pipelined scheduler (fc {dims:?}, behavioural) ==");
    let mut b = Bench::quick();
    let mut derived: Vec<(String, f64)> = Vec::new();
    let mut thr_w1 = 0.0f64;
    for &workers in &[1usize, 2, 4] {
        // span tracing stays on through the run so the BENCH_serve.json
        // record carries the per-stage wall-time breakdown of this exact
        // workload (queue wait / executor forward / crossbar solve)
        telemetry::set_level(Level::Spans);
        telemetry::clear();
        let server = Server::start_with(std::time::Duration::from_millis(2), move || {
            // scheduler width is the knob under test; module solves stay
            // single-threaded so thread counts don't multiply
            let pipeline = PipelineBuilder::new()
                .fidelity(Fidelity::Behavioural)
                .workers(1)
                .build_fc_stack(&dims, &default_device(), 23)?;
            Ok(Box::new(PipelineExecutor::new(pipeline, (h, w, c), &[1, 8, 32], workers)?)
                as Box<dyn InferenceExecutor>)
        })?;
        let client = server.client();
        let next = AtomicUsize::new(0);
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let cl = client.clone();
                let next = &next;
                let images = &images;
                s.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let img = images[i * h * w * c..(i + 1) * h * w * c].to_vec();
                    cl.classify(img).expect("serve");
                });
            }
        });
        let wall = t0.elapsed();
        // serving is a workload, not a micro-op: one timed pass per config
        b.record_once(&format!("serve behavioural w{workers} n{n}"), wall);
        let thr = n as f64 / wall.as_secs_f64().max(1e-9);
        let snap = server.metrics().snapshot();
        println!(
            "    -> {thr:.0} img/s, {} batches ({} padded), executor busy {:?} ({:.0}%)",
            snap.batches,
            snap.padded_slots,
            snap.exec_busy,
            snap.utilization(wall) * 100.0
        );
        derived.push((format!("serve_throughput_w{workers}_img_per_s"), thr));
        if workers == 1 {
            thr_w1 = thr;
        } else {
            derived.push((format!("serve_speedup_w{workers}_vs_w1"), thr / thr_w1.max(1e-9)));
        }
        server.shutdown();

        // shutdown joined the serve thread (which flushes its span buffer),
        // so the drain below sees the whole run
        telemetry::set_level(Level::Off);
        let events = telemetry::drain();
        let span_secs = |cat: &str| {
            events
                .iter()
                .filter(|e| e.cat == cat && e.ph == Ph::Span)
                .map(|e| e.dur_ns)
                .sum::<u64>() as f64
                / 1e9
        };
        let queue_s = events
            .iter()
            .filter(|e| e.name == "request")
            .flat_map(|e| e.args.iter())
            .filter(|(k, _)| *k == "queue_us")
            .map(|(_, v)| *v)
            .sum::<f64>()
            / 1e6;
        let (forward_s, solve_s) = (span_secs("forward"), span_secs("solve"));
        println!(
            "    -> span breakdown: queue {queue_s:.3}s, forward {forward_s:.3}s, \
             solve {solve_s:.3}s (summed across requests/batches)"
        );
        derived.push((format!("serve_w{workers}_span_queue_s"), queue_s));
        derived.push((format!("serve_w{workers}_span_forward_s"), forward_s));
        derived.push((format!("serve_w{workers}_span_solve_s"), solve_s));
    }
    b.table("serve path (batcher + pipelined scheduler)");
    match append_json_report("BENCH_serve.json", "bench_inference_serve", &b.rows, &derived) {
        Ok(()) => println!("(appended to BENCH_serve.json)"),
        Err(e) => eprintln!("warning: could not append BENCH_serve.json: {e}"),
    }
    Ok(())
}

/// Full-chain demo network at Behavioural vs Spice — times the end-to-end
/// batched forward with every §3 module circuit-simulated (the BN §3.3
/// subtraction + scale/offset pair, the GAP §3.5 averaging column, conv
/// banks, Fig 4 activation circuits) and asserts the spice chain stays
/// within the conformance tolerance of behavioural. Under
/// `MEMX_BENCH_QUICK=1` this is the only workload that runs — the CI smoke
/// exercising the whole-chain spice path on every push.
fn fidelity_chain_workload() -> anyhow::Result<()> {
    use memx::pipeline::demo_network;

    let (m, ws) = demo_network(0xD311)?;
    let mut rng = Rng::new(77);
    println!("\n== full-chain demo network: behavioural vs spice (conformance smoke) ==");
    let mut b = Bench::quick();
    let mut derived: Vec<(String, f64)> = Vec::new();
    let mut behav = PipelineBuilder::new().fidelity(Fidelity::Behavioural).build(&m, &ws)?;
    let mut spice = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(8)
        .workers(2)
        .build(&m, &ws)?;
    println!("    spice chain: {}", spice.describe());
    let batch: Vec<Vec<f64>> = (0..8)
        .map(|_| (0..behav.in_dim()).map(|_| rng.range_f64(-0.3, 0.3)).collect())
        .collect();
    let want = behav.forward_batch(&batch)?;
    spice.forward_batch(&batch)?; // cold pass primes the factor caches
    b.run("chain behavioural b8", || {
        black_box(behav.forward_batch(&batch).expect("behavioural chain"));
    });
    let stats = b.run("chain spice b8", || {
        black_box(spice.forward_batch(&batch).expect("spice chain"));
    });
    println!("    -> spice per-image {:.2} ms", stats.mean_secs() * 1e3 / 8.0);
    let got = spice.forward_batch(&batch)?;
    let mut worst = 0f64;
    for (g_row, w_row) in got.iter().zip(&want) {
        for (g, w) in g_row.iter().zip(w_row) {
            worst = worst.max((g - w).abs());
        }
    }
    assert!(worst < 0.3, "spice chain diverged from behavioural by {worst}");
    assert!(spice.spice_circuits() > 0, "no resident circuits at spice fidelity");
    derived.push(("chain_spice_vs_behavioural_worst_abs_err".into(), worst));
    derived.push(("chain_spice_circuits".into(), spice.spice_circuits() as f64));
    b.table("full-chain fidelity conformance");
    match append_json_report(
        "BENCH_pipeline.json",
        "bench_inference_fidelity_chain",
        &b.rows,
        &derived,
    ) {
        Ok(()) => println!("(appended to BENCH_pipeline.json)"),
        Err(e) => eprintln!("warning: could not append BENCH_pipeline.json: {e}"),
    }
    Ok(())
}

/// Eq 17/18 analytical figures over the trained manifest (skipped without
/// artifacts).
fn analytical_workload() -> anyhow::Result<()> {
    use memx::mapper::{self, MapMode};
    use memx::nn::{Manifest, WeightStore};
    use memx::power;

    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_inference: artifacts missing — skipping the analytical Fig 8 section");
        return Ok(());
    }
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;
    let net = mapper::map_network(&m, &ws, MapMode::Inverted)?;
    let t_seq = power::latency(&net, &m.device);
    let t_pipe = power::latency_pipelined(&net, &m.device);
    let e = power::energy(&net, &m.device, &t_seq);
    println!("\n== Fig 8(a,b): analytical memristor inference ==");
    println!(
        "sequential: {:.3} µs (N_m={} stages) | pipelined: {:.3} µs | energy {:.2} µJ",
        t_seq.total * 1e6,
        t_seq.n_m,
        t_pipe.total * 1e6,
        e.total * 1e6
    );
    println!(
        "vs paper baselines: GPU {:.1}x/{:.0}x (seq/pipe), CPU {:.1}x/{:.0}x",
        power::T_GPU_RTX4090 / t_seq.total,
        power::T_GPU_RTX4090 / t_pipe.total,
        power::T_CPU_I7_12700 / t_seq.total,
        power::T_CPU_I7_12700 / t_pipe.total
    );
    Ok(())
}

/// Measured digital + analog-model PJRT latency on this host.
#[cfg(feature = "runtime-xla")]
fn pjrt_workload() -> anyhow::Result<()> {
    use memx::nn::Manifest;
    use memx::runtime::{Engine, Model};
    use memx::util::bin::Dataset;

    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_inference: artifacts missing — skipping the PJRT section");
        return Ok(());
    }
    let m = Manifest::load(dir)?;
    let engine = Engine::new(dir)?;
    let ds = Dataset::load(&dir.join(&m.dataset_file))?;
    let mut b = Bench::quick(); // analog-model runs are seconds each
    for &batch in &engine.available_batches() {
        for model in [Model::Digital, Model::Analog] {
            let exec = engine.get(model, batch)?;
            let img = ds.image_len();
            let mut buf = vec![0f32; batch * img];
            for j in 0..batch {
                buf[j * img..(j + 1) * img].copy_from_slice(ds.image(j % ds.n));
            }
            let stats = b.run(&format!("{model:?} pjrt b{batch}"), || {
                exec.run(&buf).expect("execute");
            });
            println!(
                "    -> per-image {:.3} ms",
                stats.mean_secs() * 1e3 / batch as f64
            );
        }
    }
    b.table("Fig 8 — measured digital/analog-model latency on this host");
    println!("\npaper §5.2: GPU 0.1654 ms, CPU 3.3924 ms per image; analog 1.24 µs");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if std::env::var("MEMX_BENCH_QUICK").is_ok() {
        // CI smoke: full-chain spice conformance + the backend regression gate
        fidelity_chain_workload()?;
        return backend_workload(true);
    }
    pipeline_workload()?;
    backend_workload(false)?;
    serve_workload()?;
    fidelity_chain_workload()?;
    analytical_workload()?;
    #[cfg(feature = "runtime-xla")]
    pjrt_workload()?;
    Ok(())
}
