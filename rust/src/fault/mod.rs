//! Device-lifetime fault engine: conductance drift, read disturb,
//! temperature scaling, and stuck-at cells over simulated deployment time.
//!
//! The paper reports a single pristine-device accuracy number, but deployed
//! memristor arrays degrade continuously. This module models the dominant
//! lifetime failure modes and applies them **in place** to every resident
//! crossbar, so the serving pipeline never rebuilds and the cached symbolic
//! factorization (and the warm-GMRES preconditioner-reuse contract in
//! [`crate::spice`]) carries across every update:
//!
//! - **Log-time drift** — each device relaxes as `g(t) = g0 · (t/t0)^-ν`
//!   ([`FaultConfig::drift_nu`]); per-device exponents are spread by
//!   [`FaultConfig::nu_sigma`] so drift is *not* a uniform logit scaling
//!   (uniform decay is argmax-neutral and would hide real damage), and
//!   optionally scale with the as-programmed level ([`FaultConfig::nu_g`]:
//!   low-conductance states relax faster) — keyed to the *pristine*
//!   conductance so incremental steps still compose exactly.
//! - **Read disturb** — every read nudges conductance down; accumulated as
//!   [`FaultConfig::read_disturb_rate`] fractional loss per 10⁶ reads.
//! - **Temperature scaling** — the effective drift exponent grows with
//!   operating temperature: `ν_eff = ν · (1 + temp_coeff·(T - T_ref))`.
//! - **Stuck-at cells** — a fixed fraction of devices pin to the window
//!   extremes (`stuck_on_frac` → `g_on`, `stuck_off_frac` → `g_off`). The
//!   mask is a pure hash of `(seed, bank, index)`, so it is time-invariant
//!   and survives recalibration — reprogramming cannot heal dead cells.
//!
//! # Usage
//!
//! A [`FaultModel`] owns the simulated clock. Each call to
//! [`FaultModel::advance`] yields a [`FaultStep`] — an *incremental*
//! multiplicative update carrying `ln((t2+t0)/(t1+t0))`, so successive steps
//! compose exactly to the closed-form power law no matter how deployment
//! time is sliced. The step is pushed through the module tree by
//! `Pipeline::inject_faults` (every `AnalogModule` implements an
//! `inject_faults` hook), which edits placed conductances and, at
//! `Fidelity::Spice`, performs value-only netlist updates via
//! `CrossbarSim::update_conductances` — no topology change, so post-drift
//! re-solves ride the stale-LU/ILU warm paths.
//!
//! Recalibration (`Pipeline::reprogram`) restores pristine conductances,
//! re-applies programming noise and the immutable stuck mask, and resets the
//! model clock ([`FaultModel::reset_clock`]) — the online serving loop in
//! [`crate::coordinator`] triggers this from logit-margin EWMA statistics.
//! Sweep both with the `memx drift` subcommand.

use crate::mapper::layout::Placed;
use crate::util::prng::{Rng, SplitMix64};

/// Lifetime fault-model parameters. The default is a drift-only model
/// (no stuck cells) with a mild per-device exponent spread.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Base drift exponent ν in `g(t) = g0 · (t/t0)^-ν`.
    pub drift_nu: f64,
    /// Relative per-device spread of ν: device i draws
    /// `ν_i = ν · (1 + nu_sigma · u_i)` with `u_i` uniform in [-1, 1].
    pub nu_sigma: f64,
    /// Conductance dependence of the drift exponent: a device programmed
    /// at pristine level `g0` drifts with
    /// `ν_i(g0) = ν_i · (1 + nu_g · (1 - g0))` (g0 clamped to [0, 1]) —
    /// low-conductance states sit closer to the amorphous phase and relax
    /// faster. 0.0 (default) restores the conductance-independent model.
    /// Keyed to the *pristine* (as-programmed) level, not the current one,
    /// so incremental steps still compose exactly to the closed-form power
    /// law — see [`apply_step_from`].
    pub nu_g: f64,
    /// Drift reference time t0, in hours (drift is zero until t ≫ 0).
    pub t0_hours: f64,
    /// Fractional conductance loss per 10⁶ reads.
    pub read_disturb_rate: f64,
    /// Operating temperature, °C.
    pub temp_c: f64,
    /// Reference temperature at which ν was characterized, °C.
    pub temp_ref_c: f64,
    /// Per-°C relative increase of ν above `temp_ref_c`.
    pub temp_coeff: f64,
    /// Fraction of devices stuck at the window top (`g_on`).
    pub stuck_on_frac: f64,
    /// Fraction of devices stuck at the window bottom (`g_off`).
    pub stuck_off_frac: f64,
    /// Seed for the per-device hash streams (ν spread + stuck mask).
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            drift_nu: 0.05,
            nu_sigma: 0.4,
            nu_g: 0.0,
            t0_hours: 1.0,
            read_disturb_rate: 0.01,
            temp_c: 25.0,
            temp_ref_c: 25.0,
            temp_coeff: 0.02,
            stuck_on_frac: 0.0,
            stuck_off_frac: 0.0,
            seed: 0xFA17,
        }
    }
}

/// Stuck-at classification of one device under a [`FaultStep`] mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stuck {
    /// Pinned to the top of the conductance window (`g_on`).
    On,
    /// Pinned to the bottom of the window (`g_off`).
    Off,
    /// Healthy device — drift/disturb apply normally.
    Free,
}

/// Simulated deployment clock. Produces incremental [`FaultStep`]s whose
/// per-device decay factors compose exactly to the closed-form power law.
#[derive(Debug, Clone)]
pub struct FaultModel {
    cfg: FaultConfig,
    hours: f64,
    reads: u64,
}

impl FaultModel {
    pub fn new(cfg: FaultConfig) -> Self {
        FaultModel { cfg, hours: 0.0, reads: 0 }
    }

    pub fn cfg(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Simulated hours since the last (re)programming.
    pub fn hours(&self) -> f64 {
        self.hours
    }

    /// Reads accumulated since the last (re)programming.
    pub fn reads(&self) -> u64 {
        self.reads
    }

    /// Advance the clock by `hours` and `reads`, returning the incremental
    /// update to apply to every resident crossbar. Because the step carries
    /// `ln((t2+t0)/(t1+t0))`, applying N small steps equals one big step:
    /// `∏ exp(-ν·Δln) = exp(-ν·ln((t+t0)/t0)) = ((t+t0)/t0)^-ν`.
    pub fn advance(&mut self, hours: f64, reads: u64) -> FaultStep {
        let t0 = self.cfg.t0_hours.max(1e-9);
        let t1 = self.hours;
        let t2 = self.hours + hours.max(0.0);
        self.hours = t2;
        self.reads = self.reads.saturating_add(reads);
        crate::telemetry::event(crate::telemetry::Event::FaultStep { hours: t2 });
        let nu_base = (self.cfg.drift_nu
            * (1.0 + self.cfg.temp_coeff * (self.cfg.temp_c - self.cfg.temp_ref_c)))
        .max(0.0);
        FaultStep {
            ln_ratio: ((t2 + t0) / (t1 + t0)).ln().max(0.0),
            disturb: (self.cfg.read_disturb_rate * reads as f64 / 1e6).max(0.0),
            nu_base,
            nu_sigma: self.cfg.nu_sigma.max(0.0),
            nu_g: self.cfg.nu_g.max(0.0),
            stuck_on_frac: self.cfg.stuck_on_frac.clamp(0.0, 1.0),
            stuck_off_frac: self.cfg.stuck_off_frac.clamp(0.0, 1.0),
            seed: self.cfg.seed,
        }
    }

    /// Reset the drift clock after a reprogramming pass: freshly written
    /// devices restart their relaxation from t = 0.
    pub fn reset_clock(&mut self) {
        self.hours = 0.0;
        self.reads = 0;
    }
}

/// One incremental fault update: multiplicative per-device decay plus the
/// (time-invariant) stuck-at mask. `Copy`, so it is cheaply fanned out to
/// every module of a pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultStep {
    /// `ln((t2+t0)/(t1+t0))` for this increment (≥ 0).
    pub ln_ratio: f64,
    /// Read-disturb log-loss accumulated in this increment (≥ 0).
    pub disturb: f64,
    /// Temperature-adjusted base drift exponent.
    pub nu_base: f64,
    /// Relative per-device spread of the exponent.
    pub nu_sigma: f64,
    /// Conductance dependence of the exponent (see [`FaultConfig::nu_g`]).
    pub nu_g: f64,
    /// Fraction of devices stuck at `g_on`.
    pub stuck_on_frac: f64,
    /// Fraction of devices stuck at `g_off`.
    pub stuck_off_frac: f64,
    /// Hash seed shared with the owning [`FaultModel`].
    pub seed: u64,
}

impl FaultStep {
    /// A step that performs no drift and marks no stuck cells.
    pub fn noop() -> Self {
        FaultStep {
            ln_ratio: 0.0,
            disturb: 0.0,
            nu_base: 0.0,
            nu_sigma: 0.0,
            nu_g: 0.0,
            stuck_on_frac: 0.0,
            stuck_off_frac: 0.0,
            seed: 0,
        }
    }

    /// This step with drift/disturb zeroed — only the stuck-at mask remains.
    /// Used by `reprogram` hooks: rewriting a crossbar heals drift but not
    /// dead cells.
    pub fn stuck_only(&self) -> Self {
        FaultStep { ln_ratio: 0.0, disturb: 0.0, ..*self }
    }

    /// True when applying this step cannot change any conductance.
    pub fn is_noop(&self) -> bool {
        self.ln_ratio == 0.0
            && self.disturb == 0.0
            && self.stuck_on_frac == 0.0
            && self.stuck_off_frac == 0.0
    }

    /// Deterministic per-device hash: two independent uniforms for the ν
    /// spread and the stuck lottery. Stable across steps, so increments
    /// compose and the stuck mask is idempotent.
    fn device_draws(&self, bank: u64, index: usize) -> (f64, f64) {
        let mut h = SplitMix64::new(
            self.seed ^ bank ^ (index as u64).wrapping_mul(0x9E3779B97F4A7C15),
        );
        let u = |x: u64| (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        (u(h.next_u64()), u(h.next_u64()))
    }

    /// Multiplicative decay for device `index` of `bank` over this
    /// increment: `exp(-ν_i·Δln - disturb)`, always in (0, 1]. Ignores the
    /// conductance dependence (`g0 = 1`); use [`FaultStep::decay_for`]
    /// when `nu_g > 0`.
    pub fn decay(&self, bank: u64, index: usize) -> f64 {
        self.decay_for(bank, index, 1.0)
    }

    /// Like [`FaultStep::decay`] with the ν(g) conductance dependence:
    /// `g0` is the device's *pristine* (as-programmed) normalized level.
    /// Because `g0` is fixed at write time, per-device exponents are
    /// constants of the deployment window and incremental steps still
    /// compose exactly to the closed-form power law.
    pub fn decay_for(&self, bank: u64, index: usize, g0: f64) -> f64 {
        let (u, _) = self.device_draws(bank, index);
        let g_fac = 1.0 + self.nu_g * (1.0 - g0.clamp(0.0, 1.0));
        let nu_i =
            (self.nu_base * (1.0 + self.nu_sigma * (2.0 * u - 1.0)) * g_fac).max(0.0);
        (-nu_i * self.ln_ratio - self.disturb).exp().min(1.0)
    }

    /// Stuck-at classification of device `index` of `bank` — a pure
    /// function of `(seed, bank, index)`, independent of time.
    pub fn stuck(&self, bank: u64, index: usize) -> Stuck {
        if self.stuck_on_frac <= 0.0 && self.stuck_off_frac <= 0.0 {
            return Stuck::Free;
        }
        let (_, v) = self.device_draws(bank, index);
        if v < self.stuck_on_frac {
            Stuck::On
        } else if v < self.stuck_on_frac + self.stuck_off_frac {
            Stuck::Off
        } else {
            Stuck::Free
        }
    }

    /// Population-mean decay factor of this increment (drift + disturb,
    /// ignoring the stuck mask) — the behavioural-fidelity scalar used by
    /// BN/GAP modules and the energy scaling of the `memx drift` sweep.
    pub fn mean_decay(&self) -> f64 {
        (-self.nu_base * self.ln_ratio - self.disturb).exp().min(1.0)
    }
}

/// FNV-1a hash of a module/bank name — each crossbar gets an independent
/// device-hash stream so identical layouts don't drift in lockstep.
pub fn bank_seed(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Apply one step to a bank of placed devices, in place. `g_min` is the
/// bottom of the normalized conductance window (`r_on/r_off`); the top is
/// the device's own programmed ceiling (`max(g0, 1.0)` — bias devices may
/// legitimately sit above 1). Returns the mean multiplicative factor
/// actually applied (1.0 for an empty bank). Conductances never leave
/// `[g_min, cap]` and are never NaN or non-positive.
pub fn apply_step(step: &FaultStep, bank: u64, devices: &mut [Placed], g_min: f64) -> f64 {
    apply_step_from(step, bank, devices, None, g_min)
}

/// [`apply_step`] with the ν(g) reference: `pristine[i]` is device i's
/// as-programmed normalized conductance, the fixed anchor of its
/// conductance-dependent exponent. Pass the same pristine array to every
/// incremental step and N small steps compose exactly to one big step even
/// with `nu_g > 0` (keying ν to the *current* conductance would make the
/// effective exponent drift with the state and break the closed form).
/// With `pristine = None` the current conductance is used as its own
/// reference — exact only for a single application or when `nu_g == 0`.
pub fn apply_step_from(
    step: &FaultStep,
    bank: u64,
    devices: &mut [Placed],
    pristine: Option<&[f64]>,
    g_min: f64,
) -> f64 {
    if devices.is_empty() {
        return 1.0;
    }
    let g_min = g_min.max(1e-12);
    let mut ratio_sum = 0.0;
    for (i, d) in devices.iter_mut().enumerate() {
        let before = d.g_norm.max(g_min);
        let cap = before.max(1.0);
        let g0 = pristine.and_then(|p| p.get(i).copied()).unwrap_or(before);
        let after = match step.stuck(bank, i) {
            Stuck::On => cap,
            Stuck::Off => g_min,
            Stuck::Free => (before * step.decay_for(bank, i, g0)).clamp(g_min, cap),
        };
        d.g_norm = after;
        ratio_sum += after / before;
    }
    ratio_sum / devices.len() as f64
}

/// Behavioural-fidelity analogue of [`apply_step`] for signed kernel
/// weights in [-1, 1] (conv banks keep folded kernels, not placed
/// devices, below `Fidelity::Spice`): drift shrinks magnitudes, stuck-ON
/// saturates to ±1 preserving sign, stuck-OFF zeroes the weight.
pub fn apply_step_signed(step: &FaultStep, bank: u64, weights: &mut [f64]) {
    apply_step_signed_from(step, bank, weights, None);
}

/// [`apply_step_signed`] with the ν(g) reference (see [`apply_step_from`]):
/// `pristine[i]` is the as-programmed signed weight; its magnitude is the
/// conductance proxy anchoring the device's drift exponent.
pub fn apply_step_signed_from(
    step: &FaultStep,
    bank: u64,
    weights: &mut [f64],
    pristine: Option<&[f64]>,
) {
    for (i, w) in weights.iter_mut().enumerate() {
        let g0 = pristine.and_then(|p| p.get(i).copied()).unwrap_or(*w).abs();
        *w = match step.stuck(bank, i) {
            Stuck::On => {
                if *w < 0.0 {
                    -1.0
                } else {
                    1.0
                }
            }
            Stuck::Off => 0.0,
            Stuck::Free => (*w * step.decay_for(bank, i, g0)).clamp(-1.0, 1.0),
        };
    }
}

/// Re-apply programming noise to a bank after a pristine restore — the
/// write operation of a recalibration pass. Same statistics as
/// [`crate::mapper::apply_prog_noise_analog`], but seeded per
/// `(seed, bank, generation)` so successive recalibrations draw fresh
/// noise instead of replaying the original write.
pub fn reprogram_noise(
    devices: &mut [Placed],
    sigma: f64,
    seed: u64,
    bank: u64,
    generation: u64,
) {
    if sigma <= 0.0 || devices.is_empty() {
        return;
    }
    let mut rng = Rng::new(seed ^ bank ^ generation.wrapping_mul(0x9E3779B97F4A7C15));
    crate::mapper::apply_prog_noise_analog(devices, sigma, &mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bank(n: usize, g: f64) -> Vec<Placed> {
        (0..n).map(|i| Placed { row: i, col: 0, g_norm: g }).collect()
    }

    #[test]
    fn steps_compose_to_closed_form() {
        // many small advances must equal one big advance, per device
        let cfg = FaultConfig { nu_sigma: 0.5, ..Default::default() };
        let mut split = FaultModel::new(cfg);
        let mut whole = FaultModel::new(cfg);
        let mut g_split = 1.0f64;
        for _ in 0..10 {
            let s = split.advance(10.0, 0);
            g_split *= s.decay(7, 3);
        }
        let g_whole = whole.advance(100.0, 0).decay(7, 3);
        assert!((g_split - g_whole).abs() < 1e-12, "{g_split} vs {g_whole}");
    }

    #[test]
    fn conductance_dependent_drift_composes() {
        // with nu_g on, slicing the window must still telescope exactly,
        // because the exponent is anchored to the fixed pristine level
        let cfg = FaultConfig { nu_g: 1.5, nu_sigma: 0.5, ..Default::default() };
        let mut split = FaultModel::new(cfg);
        let mut g_split = 1.0f64;
        for _ in 0..10 {
            g_split *= split.advance(10.0, 0).decay_for(7, 3, 0.2);
        }
        let g_whole = FaultModel::new(cfg).advance(100.0, 0).decay_for(7, 3, 0.2);
        assert!((g_split - g_whole).abs() < 1e-12, "{g_split} vs {g_whole}");
    }

    #[test]
    fn low_conductance_devices_drift_faster() {
        let cfg =
            FaultConfig { nu_g: 2.0, nu_sigma: 0.0, read_disturb_rate: 0.0, ..Default::default() };
        let s = FaultModel::new(cfg).advance(1000.0, 0);
        assert!(s.decay_for(1, 0, 0.1) < s.decay_for(1, 0, 0.9));
        // at the window top the dependence vanishes: decay() is the g0=1 case
        assert_eq!(s.decay_for(1, 0, 1.0).to_bits(), s.decay(1, 0).to_bits());
    }

    #[test]
    fn apply_step_from_pristine_slices_compose() {
        let cfg = FaultConfig {
            drift_nu: 0.1,
            nu_sigma: 0.3,
            nu_g: 1.0,
            read_disturb_rate: 0.0,
            ..Default::default()
        };
        let pristine: Vec<f64> = (0..100).map(|i| 0.1 + 0.8 * (i as f64) / 99.0).collect();
        let mk = || -> Vec<Placed> {
            pristine
                .iter()
                .enumerate()
                .map(|(i, &g)| Placed { row: i, col: 0, g_norm: g })
                .collect()
        };
        let g_min = 1e-4;
        let mut split = FaultModel::new(cfg);
        let mut sliced = mk();
        for _ in 0..4 {
            apply_step_from(&split.advance(25.0, 0), 3, &mut sliced, Some(&pristine), g_min);
        }
        let mut whole = mk();
        apply_step_from(
            &FaultModel::new(cfg).advance(100.0, 0),
            3,
            &mut whole,
            Some(&pristine),
            g_min,
        );
        for (a, b) in sliced.iter().zip(&whole) {
            assert!((a.g_norm - b.g_norm).abs() < 1e-12, "{} vs {}", a.g_norm, b.g_norm);
        }
    }

    #[test]
    fn decay_bounded_and_monotone() {
        let cfg = FaultConfig { nu_sigma: 0.9, ..Default::default() };
        let mut m = FaultModel::new(cfg);
        let s = m.advance(1000.0, 5_000_000);
        for i in 0..200 {
            let d = s.decay(1, i);
            assert!(d > 0.0 && d <= 1.0 && d.is_finite(), "decay {d}");
        }
        // longer exposure decays at least as much
        let s2 = FaultModel::new(cfg).advance(10.0, 0);
        let s3 = FaultModel::new(cfg).advance(10_000.0, 0);
        for i in 0..50 {
            assert!(s3.decay(2, i) <= s2.decay(2, i));
        }
    }

    #[test]
    fn stuck_mask_is_time_invariant() {
        let cfg = FaultConfig {
            stuck_on_frac: 0.1,
            stuck_off_frac: 0.1,
            ..Default::default()
        };
        let a = FaultModel::new(cfg).advance(1.0, 0);
        let b = FaultModel::new(cfg).advance(5000.0, 99);
        let mut on = 0;
        let mut off = 0;
        for i in 0..1000 {
            assert_eq!(a.stuck(3, i), b.stuck(3, i), "mask must not depend on time");
            match a.stuck(3, i) {
                Stuck::On => on += 1,
                Stuck::Off => off += 1,
                Stuck::Free => {}
            }
        }
        assert!((50..200).contains(&on), "stuck-on count {on}");
        assert!((50..200).contains(&off), "stuck-off count {off}");
    }

    #[test]
    fn apply_step_respects_window() {
        let cfg = FaultConfig {
            drift_nu: 0.3,
            nu_sigma: 0.8,
            stuck_on_frac: 0.05,
            stuck_off_frac: 0.05,
            ..Default::default()
        };
        let step = FaultModel::new(cfg).advance(10_000.0, 10_000_000);
        let g_min = 100.0 / 16000.0;
        let mut devs = bank(500, 0.7);
        let factor = apply_step(&step, 11, &mut devs, g_min);
        assert!(factor > 0.0 && factor <= 1.1, "mean factor {factor}");
        for d in &devs {
            assert!(d.g_norm.is_finite() && d.g_norm >= g_min && d.g_norm <= 1.0);
        }
    }

    #[test]
    fn stuck_only_heals_drift_not_cells() {
        let cfg = FaultConfig {
            drift_nu: 0.3,
            stuck_off_frac: 0.2,
            ..Default::default()
        };
        let step = FaultModel::new(cfg).advance(1000.0, 0);
        let g_min = 1.0 / 160.0;
        let mut devs = bank(200, 0.8);
        apply_step(&step, 5, &mut devs, g_min);
        // pristine restore + stuck-only re-application
        let mut restored = bank(200, 0.8);
        apply_step(&step.stuck_only(), 5, &mut restored, g_min);
        for (i, d) in restored.iter().enumerate() {
            match step.stuck(5, i) {
                Stuck::Off => assert!((d.g_norm - g_min).abs() < 1e-15),
                Stuck::Free => assert!((d.g_norm - 0.8).abs() < 1e-15, "drift must heal"),
                Stuck::On => assert!((d.g_norm - 1.0).abs() < 1e-15),
            }
        }
    }

    #[test]
    fn noop_step_changes_nothing() {
        let step = FaultStep::noop();
        assert!(step.is_noop());
        let mut devs = bank(32, 0.42);
        let f = apply_step(&step, 9, &mut devs, 1e-3);
        assert!((f - 1.0).abs() < 1e-15);
        assert!(devs.iter().all(|d| (d.g_norm - 0.42).abs() < 1e-15));
    }

    #[test]
    fn signed_weights_stay_in_unit_interval() {
        let cfg = FaultConfig {
            drift_nu: 0.2,
            nu_sigma: 0.7,
            stuck_on_frac: 0.1,
            stuck_off_frac: 0.1,
            ..Default::default()
        };
        let step = FaultModel::new(cfg).advance(500.0, 1_000_000);
        let mut w: Vec<f64> =
            (0..300).map(|i| ((i as f64 * 0.37).sin())).collect();
        apply_step_signed(&step, 21, &mut w);
        for v in &w {
            assert!(v.is_finite() && v.abs() <= 1.0);
        }
    }

    #[test]
    fn bank_seeds_differ() {
        assert_ne!(bank_seed("stem.conv_ci0_co1"), bank_seed("stem.conv_ci0_co2"));
    }
}
